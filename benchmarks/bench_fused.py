"""Fused streaming raster throughput: ``pallas_fused`` vs ``pallas_binned``
at 100k–1M Gaussians.

The unfused ladder computes per-Gaussian features for the whole visible set,
materializes them, then blends; the fused pipeline
(``repro.kernels.fused_raster``) streams each tile's compacted *raw* records
through projection/covariance/SH directly into alpha blending inside one
Pallas kernel — features for a chunk exist only in registers, the in-kernel
early exit stops a tile's chunk loop once every pixel's transmittance
saturates, and banded SH turns the distance-LOD degree into skipped basis
FLOPs per chunk. This benchmark measures that trade on the serving shape
(cameras inside the cloud, frustum-culled SceneTree):

* sequential req/s of ``pallas_binned`` vs ``pallas_fused`` (early exit on,
  the production setting) and the LOD-banded fused variant;
* max pixel error of fused-without-early-exit vs the unfused path (pure
  kernel-arithmetic difference — must be ~1e-6) and of early-exit-on vs
  off (bounded by the 1/255 transmittance floor);
* a roofline read of the compiled fused render (``benchmarks.roofline``).

``--tiny`` is the CI smoke: asserts fused >= 0.9x unfused req/s and exact
(<=1e-6) images on a small clustered scene.

    PYTHONPATH=src python -m benchmarks.bench_fused [--tiny]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (
    RenderConfig,
    build_scene_tree,
    clustered_gaussians,
    look_at_camera,
    random_gaussians,
    visibility_stats,
)
from repro.core.render import render_jit, render_with_stats
from repro.obs.metrics import Registry
from repro.obs.pipeline import fold_render_stats

IMAGE_SIZE = 256
CAMERAS = 2
ITERS = 2
LEAF_SIZE = 256
# (scene kind, sizes): uniform capped at 500k to bound bench wall time.
SWEEP = (
    ("uniform", (100_000, 500_000)),
    ("clustered", (100_000, 500_000, 1_000_000)),
)
LOD_THRESHOLDS = (0.2, 0.5)

TINY_IMAGE_SIZE = 96
TINY_N = 20_000
TINY_LEAF = 128


def make_scene(kind: str, n: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    if kind == "uniform":
        return random_gaussians(key, n, extent=2.0)
    return clustered_gaussians(key, n, num_clusters=12, extent=2.0)


def inside_cameras(num: int, size: int, radius: float = 0.8):
    """Cameras inside the cloud looking outward (the unbounded-capture
    serving shape — any one view sees a fraction of the scene)."""
    cams = []
    for i in range(num):
        th = 2.0 * np.pi * i / num
        eye = (radius * np.cos(th), 0.2, radius * np.sin(th))
        tgt = (3 * radius * np.cos(th), 0.2, 3 * radius * np.sin(th))
        cams.append(look_at_camera(eye, tgt, width=size, height=size))
    return cams


def _seq_req_s(model, cams, cfg, iters: int) -> tuple[float, list]:
    """Sequential per-request throughput; returns (req/s, last images)."""
    render_jit(model, cams[0], cfg).block_until_ready()  # compile+warm
    walls, imgs = [], []
    for _ in range(iters):
        imgs = []
        t0 = time.perf_counter()
        for cam in cams:
            imgs.append(render_jit(model, cam, cfg))
        jax.block_until_ready(imgs)
        walls.append(time.perf_counter() - t0)
    walls.sort()
    return len(cams) / walls[len(walls) // 2], imgs


def _max_err(a_imgs, b_imgs) -> float:
    return max(
        float(jnp.abs(a - b).max()) for a, b in zip(a_imgs, b_imgs)
    )


def _fused_roofline(tree, cam, cfg) -> dict:
    """Roofline read of the compiled fused render executable."""
    import benchmarks.roofline as R

    compiled = render_jit.lower(tree, cam, cfg).compile()
    rep = R.analyze(compiled.as_text(), num_partitions=1)
    return rep.to_dict()


def bench_scene(
    kind: str,
    n: int,
    *,
    image_size: int,
    leaf_size: int,
    iters: int,
    roofline: bool = False,
) -> dict:
    g = make_scene(kind, n)
    tree = build_scene_tree(g, leaf_size=leaf_size)
    cams = inside_cameras(CAMERAS, image_size)

    base = RenderConfig(raster_path="pallas_binned", cull=True)
    probe = base.replace(lod_thresholds=LOD_THRESHOLDS)
    stats = [visibility_stats(tree, c, probe) for c in cams]
    # Conservative static capacity (in chunks): every visible chunk of
    # every camera fits, so culling never drops content and the fused vs
    # unfused comparison is over identical visible sets.
    capacity = max(s["num_visible"] for s in stats)
    cfg_binned = base.replace(visible_capacity=capacity)
    cfg_fused = cfg_binned.replace(raster_path="pallas_fused")
    cfg_fused_lod = cfg_fused.replace(lod_thresholds=LOD_THRESHOLDS)

    binned_req_s, binned_imgs = _seq_req_s(tree, cams, cfg_binned, iters)
    fused_req_s, _ = _seq_req_s(tree, cams, cfg_fused, iters)
    lod_req_s, _ = _seq_req_s(tree, cams, cfg_fused_lod, iters)

    # Error decomposition: early-exit OFF isolates the in-kernel feature
    # arithmetic (must match the unfused path to float rounding); the
    # ee-on-vs-off delta is the bounded transmittance-saturation drop.
    noee_imgs = [
        render_jit(tree, c, cfg_fused.replace(early_exit=False))
        for c in cams
    ]
    ee_imgs = [render_jit(tree, c, cfg_fused) for c in cams]
    fused_err = _max_err(noee_imgs, binned_imgs)
    ee_err = _max_err(ee_imgs, noee_imgs)

    speedup = fused_req_s / binned_req_s
    tag = f"fused/{kind}_{n}"
    emit(f"{tag}_binned_req_s", 1e6 / binned_req_s, f"{binned_req_s:.2f}req_s")
    emit(
        f"{tag}_fused_req_s",
        1e6 / fused_req_s,
        f"{speedup:.2f}x_binned_err{fused_err:.1e}",
    )
    emit(
        f"{tag}_fused_lod_req_s",
        1e6 / lod_req_s,
        f"{lod_req_s / binned_req_s:.2f}x_binned",
    )

    # Pipeline-health registry snapshot (repro.obs): the fused kernel's
    # in-kernel counters (chunks before early exit, lanes blended, max SH
    # band) plus cull visibility for the first camera, folded under the
    # same canonical series names the server's /metrics endpoint exports.
    registry = Registry()
    _, st = render_with_stats(
        tree, cams[0], cfg_fused.replace(collect_stats=True)
    )
    kernel_agg = fold_render_stats(
        registry, st, scene=kind, gaussians=str(n)
    )
    emit(
        f"{tag}_early_exit_savings",
        kernel_agg["early_exit_savings"],
        f"{kernel_agg['early_exit_savings']:.1%}_of_assigned_chunks",
    )

    entry = {
        "gaussians": n,
        "image_size": image_size,
        "leaf_size": leaf_size,
        "visible_capacity_chunks": capacity,
        "visible_fraction_mean": float(
            np.mean([s["visible_fraction"] for s in stats])
        ),
        "kernel_stats": kernel_agg,
        "registry": registry.snapshot(),
        "binned_req_s": binned_req_s,
        "fused_req_s": fused_req_s,
        "fused_speedup": speedup,
        "fused_lod_req_s": lod_req_s,
        "fused_lod_speedup": lod_req_s / binned_req_s,
        "fused_max_err_vs_binned": fused_err,
        "early_exit_max_err": ee_err,
    }
    if roofline:
        entry["roofline"] = _fused_roofline(tree, cams[0], cfg_fused)
    return entry


def main(argv: tuple[str, ...] | list[str] = ()) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny",
        action="store_true",
        help="CI smoke: small clustered scene, asserts fused >= 0.9x "
        "unfused req/s and <= 1e-6 images",
    )
    args = ap.parse_args(list(argv))

    if args.tiny:
        entry = bench_scene(
            "clustered",
            TINY_N,
            image_size=TINY_IMAGE_SIZE,
            leaf_size=TINY_LEAF,
            iters=1,
        )
        assert entry["fused_max_err_vs_binned"] <= 1e-6, entry
        assert entry["early_exit_max_err"] <= 1.0 / 255.0, entry
        # Perf floor, not target: the CI runner is noisy and tiny scenes
        # under-fill the supertiles; the 1.5x headline is the full run's.
        assert entry["fused_speedup"] >= 0.9, (
            f"fused slower than 0.9x unfused: {entry}"
        )
        print(
            f"# tiny smoke OK: fused {entry['fused_speedup']:.2f}x unfused, "
            f"err {entry['fused_max_err_vs_binned']:.1e}, "
            f"early-exit delta {entry['early_exit_max_err']:.1e}"
        )
        return {"clustered": {str(TINY_N): entry}}

    metrics: dict = {}
    for kind, sizes in SWEEP:
        metrics[kind] = {}
        for n in sizes:
            metrics[kind][str(n)] = bench_scene(
                kind,
                n,
                image_size=IMAGE_SIZE,
                leaf_size=LEAF_SIZE,
                iters=ITERS,
                # One roofline read at the headline config.
                roofline=(kind == "clustered" and n == 500_000),
            )
    return metrics


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
