"""Markdown report generators.

* ``trajectory_table``: collate the checked-in ``BENCH_PR*.json`` files
  (one per PR, written by ``benchmarks/run.py``) into a single
  perf-trajectory table — each row is one PR's headline metrics, so the
  growth of the raster stack (binned -> compact -> culled -> fused ->
  quantized) reads as one table. ``run.py`` writes it to
  ``BENCH_TRAJECTORY.md`` after every full benchmark run.
* dry-run / roofline tables from ``results/dryrun/*.json`` (the LM-substrate
  experiments in EXPERIMENTS.md).
* ``perfguard_table``: the ``[tool.perfguard]`` budgets evaluated against
  the newest BENCH file (``--section perfguard``) — the markdown twin of
  ``python -m tools.perfguard check``.

Usage: PYTHONPATH=src:. python -m benchmarks.report [--section trajectory]
Prints markdown to stdout.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re

ARCH_ORDER = [
    "qwen2-7b",
    "h2o-danube-1.8b",
    "tinyllama-1.1b",
    "starcoder2-7b",
    "mamba2-1.3b",
    "qwen3-moe-30b-a3b",
    "qwen3-moe-235b-a22b",
    "zamba2-2.7b",
    "whisper-small",
    "internvl2-2b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _dig(d: dict, *keys, default=None):
    for k in keys:
        if not isinstance(d, dict) or k not in d:
            return default
        d = d[k]
    return d


def _largest_scene(section: dict | None) -> dict | None:
    """Deepest entry of a ``{kind: {str(n): entry}}`` sweep: the clustered
    (or only) kind at its largest scene size."""
    if not isinstance(section, dict) or not section:
        return None
    kind = "clustered" if "clustered" in section else sorted(section)[0]
    sizes = section.get(kind)
    if not isinstance(sizes, dict) or not sizes:
        return None
    return sizes[max(sizes, key=int)]


def _scalar(x):
    """Reduce a ``--trials N`` sample list to its median; pass scalars
    (and anything non-numeric) through. Keeps the tables schema-agnostic
    across the scalar-leaf (trials=1) and list-leaf (trials>1) BENCH
    forms."""
    if isinstance(x, list) and x and all(
        isinstance(v, (int, float)) and not isinstance(v, bool) for v in x
    ):
        s = sorted(x)
        mid = len(s) // 2
        return s[mid] if len(s) % 2 else 0.5 * (s[mid - 1] + s[mid])
    return x


def _fmt(x, spec: str = ".2f", suffix: str = "") -> str:
    x = _scalar(x)
    if x is None:
        return "—"
    return f"{x:{spec}}{suffix}"


def trajectory_table(repo_root: str | os.PathLike) -> str:
    """One perf-trajectory markdown table over every ``BENCH_PR*.json``.

    Columns are the headline metric each PR introduced; earlier PRs show
    "—" for sections that did not exist yet. Robust to missing files and
    missing keys — a reshuffled schema degrades to a dash, never a crash.
    A PR *inside* the covered range with no BENCH file (a PR that changed
    no measured surface) renders as an explicit all-dash row, so the table
    says "not measured" instead of silently renumbering the trajectory.
    """
    rows = []
    by_pr = {
        int(re.search(r"BENCH_PR(\d+)", p).group(1)): p
        for p in glob.glob(os.path.join(os.fspath(repo_root), "BENCH_PR*.json"))
    }
    for pr in range(min(by_pr), max(by_pr) + 1) if by_pr else ():
        if pr not in by_pr:
            rows.append(f"| PR {pr} | — | — | — | — | — | — | — |")
            continue
        with open(by_pr[pr]) as f:
            d = json.load(f)
        clu = _dig(d, "bench_table2_throughput", "render", "scenes", "clustered")
        fused = _largest_scene(d.get("bench_fused"))
        culled = _largest_scene(d.get("bench_culling"))
        comp = _largest_scene(d.get("bench_compress"))
        rows.append(
            "| PR {pr} | {binned} | {compact} | {serve} | {cull} | {fused} "
            "| {bytes} | {psnr} |".format(
                pr=pr,
                binned=_fmt(_dig(clu, "speedup_vs_dense", "binned"), suffix="x"),
                compact=_fmt(
                    _dig(clu, "compact_vs_block_speedup"), suffix="x"
                ),
                serve=_fmt(_dig(d, "bench_serving", "server", "req_s")),
                cull=_fmt(
                    _dig(culled, "culled_speedup"),
                    suffix=f"x@{int(_scalar(_dig(culled, 'gaussians', default=0))) // 1000}k",
                ) if culled else "—",
                fused=_fmt(_dig(fused, "fused_speedup"), suffix="x"),
                bytes=_fmt(_dig(comp, "byte_ratio"), ".3f", "x f32")
                if comp else "—",
                psnr=_fmt(_dig(comp, "psnr_db"), ".1f", " dB")
                if comp else "—",
            )
        )
    header = (
        "### Perf trajectory (one row per PR's BENCH_PR*.json)\n\n"
        "| PR | binned vs dense | compact vs block | serve req/s "
        "| culled speedup | fused speedup | quant bytes | quant PSNR |\n"
        "|---|---|---|---|---|---|---|---|\n"
    )
    return header + "\n".join(rows) + "\n"


def obs_table(repo_root: str | os.PathLike) -> str:
    """Pipeline-health table from the newest registry snapshot on disk.

    Walks ``BENCH_PR*.json`` newest-first for a ``repro.obs`` registry
    snapshot (``bench_obs`` first, then the per-scene snapshots inside
    ``bench_fused`` / ``bench_table2_throughput``) and renders every
    series: gauges/counters as values, histograms as count + p50/p95.
    The series names match the render server's ``/metrics`` exposition,
    so this table reads like a point-in-time scrape of the benchmark.
    """
    paths = sorted(
        glob.glob(os.path.join(os.fspath(repo_root), "BENCH_PR*.json")),
        key=lambda p: int(re.search(r"BENCH_PR(\d+)", p).group(1)),
        reverse=True,
    )
    snap, source = None, None
    for path in paths:
        with open(path) as f:
            d = json.load(f)
        snap = (
            _dig(d, "bench_obs", "registry")
            or _dig(_largest_scene(d.get("bench_fused")) or {}, "registry")
            or _dig(d, "bench_table2_throughput", "render", "registry")
        )
        if snap:
            source = os.path.basename(path)
            break
    if not snap:
        return (
            "### Pipeline health\n\nNo registry snapshot found in any "
            "BENCH_PR*.json — run `python -m benchmarks.run` (or "
            "`python -m benchmarks.bench_obs`).\n"
        )
    lines = [
        f"### Pipeline health (`repro.obs` registry snapshot, {source})\n",
        "| metric | type | labels | value |",
        "|---|---|---|---|",
    ]
    for name in sorted(snap):
        fam = snap[name]
        for s in fam.get("series", []):
            labels = ",".join(
                f"{k}={v}" for k, v in sorted(s.get("labels", {}).items())
            ) or "—"
            if fam.get("type") == "histogram":
                sm = s.get("summary", {})
                value = (
                    f"n={sm.get('count', 0)} "
                    f"p50={_fmt(sm.get('p50'), '.4g')} "
                    f"p95={_fmt(sm.get('p95'), '.4g')}"
                )
            else:
                value = _fmt(s.get("value"), ".4g")
            lines.append(f"| {name} | {fam.get('type')} | {labels} | {value} |")
    return "\n".join(lines) + "\n"


def perfguard_table(repo_root: str | os.PathLike) -> str:
    """Budget status table: every ``[tool.perfguard]`` budget evaluated
    against the newest BENCH file (same decision logic as
    ``python -m tools.perfguard check``, rendered as markdown)."""
    import pathlib
    import sys

    root = pathlib.Path(os.fspath(repo_root))
    sys.path.insert(0, os.fspath(root))  # tools/ lives at the repo root
    try:
        from tools.perfguard import bench as bench_io
        from tools.perfguard.budgets import evaluate_budgets
        from tools.perfguard.config import load_config
    finally:
        sys.path.pop(0)

    cfg = load_config(root)
    bench_path = bench_io.latest_bench(root, cfg["bench_glob"])
    if bench_path is None:
        return (
            "### Perf budgets\n\nNo BENCH results found — run "
            "`python -m benchmarks.run` first.\n"
        )
    bench = bench_io.load_bench(bench_path)
    baseline = bench_io.load_baseline(root / cfg["baseline"])
    results = evaluate_budgets(
        cfg["budgets"], bench, baseline,
        profile=bench_io.bench_profile(bench),
    )
    lines = [
        f"### Perf budgets (`tool.perfguard` vs {bench_path.name})\n",
        "| budget | status | detail |",
        "|---|---|---|",
    ]
    for r in results:
        lines.append(f"| {r.budget.name} | {r.status} | {r.message} |")
    return "\n".join(lines) + "\n"


def load(results_dir: str) -> dict:
    out = {}
    for path in glob.glob(os.path.join(results_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        mesh = r.get("mesh_tag") or r.get("mesh")
        out[(r["arch"], r["shape"], mesh)] = r
    return out


def _fmt_s(x: float) -> str:
    if x >= 0.01:
        return f"{x:.2f}"
    return f"{x:.2e}"


def roofline_table(cells: dict, mesh: str) -> str:
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck "
        "| MODEL_FLOPS/dev | useful ratio | bytes/dev (args+tmp) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, mesh))
            if r is None:
                continue
            if r.get("status") == "skipped":
                lines.append(
                    f"| {arch} | {shape} | — | — | — | SKIP: {r['reason'][:40]} | — | — | — |"
                )
                continue
            if r.get("status") != "ok":
                lines.append(f"| {arch} | {shape} | FAILED | | | | | | |")
                continue
            rf = r["roofline"]
            mem = r.get("memory_analysis", {})
            dev_bytes = (mem.get("argument_size_in_bytes") or 0) + (
                mem.get("temp_size_in_bytes") or 0
            )
            useful = rf.get("useful_ratio")
            lines.append(
                "| {a} | {s} | {c} | {m} | {k} | **{b}** | {mf:.2e} | {u} | {db:.1f} GB |".format(
                    a=arch,
                    s=shape,
                    c=_fmt_s(rf["compute_s"]),
                    m=_fmt_s(rf["memory_s"]),
                    k=_fmt_s(rf["collective_s"]),
                    b=rf["bottleneck"],
                    mf=rf.get("model_flops") or 0,
                    u=f"{useful:.2f}" if useful else "—",
                    db=dev_bytes / 1e9,
                )
            )
    return "\n".join(lines)


def dryrun_table(cells: dict) -> str:
    lines = [
        "| arch | shape | mesh | status | compile (s) | collectives in HLO | HLO size |",
        "|---|---|---|---|---|---|---|",
    ]
    for mesh in ["pod16x16", "pod2x16x16"]:
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                r = cells.get((arch, shape, mesh))
                if r is None:
                    continue
                if r.get("status") == "skipped":
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | SKIP ({r['reason'][:48]}) | — | — | — |"
                    )
                    continue
                cc = r.get("collective_op_counts", {})
                csum = ", ".join(
                    f"{k.split('-')[-1] if k != 'all-to-all' else 'a2a'}:{v}"
                    for k, v in cc.items()
                    if v
                )
                lines.append(
                    "| {a} | {s} | {m} | {st} | {t:.0f} | {c} | {h:.1f} MB |".format(
                        a=arch,
                        s=shape,
                        m=mesh,
                        st=r["status"].upper(),
                        t=r.get("compile_seconds", 0),
                        c=csum or "none",
                        h=r.get("hlo_bytes", 0) / 1e6,
                    )
                )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument(
        "--section",
        default="all",
        choices=["all", "roofline", "dryrun", "trajectory", "obs", "perfguard"],
    )
    ap.add_argument(
        "--repo",
        default=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        help="repo root holding the BENCH_PR*.json files (trajectory)",
    )
    args = ap.parse_args()
    if args.section == "trajectory":
        print(trajectory_table(args.repo))
        return
    if args.section == "obs":
        print(obs_table(args.repo))
        return
    if args.section == "perfguard":
        print(perfguard_table(args.repo))
        return
    cells = load(args.results)
    if args.section in ("all", "dryrun"):
        print("### Dry-run status (both meshes)\n")
        print(dryrun_table(cells))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline terms — single pod 16x16 (256 chips)\n")
        print(roofline_table(cells, "pod16x16"))
        print()
        print("### Roofline terms — multi-pod 2x16x16 (512 chips)\n")
        print(roofline_table(cells, "pod2x16x16"))


if __name__ == "__main__":
    main()
