"""Roofline analysis from compiled (post-SPMD, per-device) HLO text.

Why a custom parser: on this container ``compiled.cost_analysis()`` counts
``while`` (lax.scan) bodies ONCE — a 94-layer model would be under-counted
94x. This module parses ``compiled.as_text()`` directly:

  * per-computation FLOPs from ``dot``/``convolution`` ops (operand shapes
    resolved through a per-computation symbol table),
  * per-computation collective wire bytes (ring-model formulas) from
    ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
    ``collective-permute``,
  * an HBM-traffic estimate: top-level op operand+output bytes (fusions
    encapsulate what XLA keeps in registers/VMEM, so top-level buffers are a
    reasonable proxy for materialized traffic),
  * a call-graph walk (fusion ``calls=``, ``to_apply=``, while ``body=``)
    that multiplies nested computations by their statically-parsed while trip
    counts (read from the loop-condition ``compare`` constant).

Roofline terms (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. All HLO quantities here are per-device (post-partition),
so each term is   seconds = per_device_quantity / per_chip_rate.
"""

from __future__ import annotations

import dataclasses
import math
import re

PEAK_FLOPS = 197e12  # bf16 MXU, per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

DTYPE_BYTES = {
    "pred": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
    "s4": 1,
    "u4": 1,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _parse_shape(text: str) -> tuple[str, tuple[int, ...]] | None:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dtype = m.group(1)
    if dtype not in DTYPE_BYTES:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",") if d) if m.group(2) else ()
    return dtype, dims


def _all_shapes(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dtype = m.group(1)
        if dtype not in DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d) if m.group(2) else ()
        out.append((dtype, dims))
    return out


def _nbytes(shape: tuple[str, tuple[int, ...]]) -> int:
    dtype, dims = shape
    return DTYPE_BYTES[dtype] * int(math.prod(dims)) if dims else DTYPE_BYTES[dtype]


@dataclasses.dataclass
class ComputationStats:
    flops: float = 0.0
    collective_bytes: float = 0.0
    hbm_bytes: float = 0.0
    calls: list[tuple[str, str]] = dataclasses.field(default_factory=list)
    # (callee_name, kind) with kind in {plain, while_body}
    while_trips: dict[str, float] = dataclasses.field(default_factory=dict)


_SKIP_TRAFFIC_OPS = {
    "parameter",
    "constant",
    "get-tuple-element",
    "tuple",
    "bitcast",
    "bitcast-convert",
    "after-all",
    "partition-id",
    "replica-id",
    "iota",
    "reshape",  # layout-preserving reshapes are free on TPU
}

_OPNAME_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

# Fusions whose name tokens (ignoring 'fusion'/'wrapped'/digits) consist
# ONLY of these are layout/dtype plumbing that the TPU backend fuses into
# consumers (see HBM-proxy note in parse_hlo). A plain anonymous "fusion.N"
# is real compute and is NOT skipped.
_DATA_MOVEMENT_CORE = {
    "convert",
    "copy",
    "transpose",
    "bitcast",
    "broadcast",
    "reshape",
}
_DATA_MOVEMENT_IGNORE = {"fusion", "wrapped"}


def split_computations(hlo: str) -> dict[str, list[str]]:
    """Computation name -> list of body lines."""
    comps: dict[str, list[str]] = {}
    current = None
    depth = 0
    for line in hlo.splitlines():
        stripped = line.strip()
        if current is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(|=\s*\().*\{", line)
            if m and line.rstrip().endswith("{"):
                current = m.group(1)
                comps[current] = []
                depth = 1
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            current = None
            continue
        comps[current].append(line)
    return comps


def _group_size(line: str, num_partitions: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return num_partitions


_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _operand_names(operand_text: str) -> list[str]:
    """Operand variable names from an op's argument list.

    Handles both HLO operand styles: bare names (``dot(%a, %b)``) and fully
    typed (``dot(f32[32,512]{1,0} %a, f32[512,128]{1,0} %b)``) — the latter
    is what compiled modules print, and naive comma-splitting breaks on the
    commas inside the shapes.
    """
    named = _OPERAND_NAME_RE.findall(operand_text)
    if named:
        return named
    return [o.strip() for o in operand_text.split(",") if o.strip()]


def _dot_flops(line: str, symbols: dict[str, tuple[str, tuple[int, ...]]]) -> float:
    out_shape = _parse_shape(line.split("=", 1)[1])
    if out_shape is None:
        return 0.0
    out_elems = math.prod(out_shape[1]) if out_shape[1] else 1
    # contracted extent from lhs operand shape + lhs_contracting_dims
    mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    ops = re.search(r"\b(?:dot|convolution)\(([^)]*)\)", line)
    contracted = 1
    if mdims and ops:
        operand_names = _operand_names(ops.group(1))
        lhs = symbols.get(operand_names[0]) if operand_names else None
        if lhs:
            for d in mdims.group(1).split(","):
                if d:
                    contracted *= lhs[1][int(d)]
    return 2.0 * out_elems * contracted


def _conv_flops(line: str, symbols: dict[str, tuple[str, tuple[int, ...]]]) -> float:
    out_shape = _parse_shape(line.split("=", 1)[1])
    if out_shape is None:
        return 0.0
    out_elems = math.prod(out_shape[1]) if out_shape[1] else 1
    ops = re.search(r"convolution\(([^)]*)\)", line)
    kernel_elems = 1
    out_feats = 1
    if ops:
        names = _operand_names(ops.group(1))
        if len(names) >= 2 and names[1] in symbols:
            kshape = symbols[names[1]][1]
            kernel_elems = math.prod(kshape) if kshape else 1
            out_feats = kshape[-1] if kshape else 1
    mg = re.search(r"feature_group_count=(\d+)", line)
    groups = int(mg.group(1)) if mg else 1
    # flops = 2 * out_elems * (kernel work per output feature)
    return 2.0 * out_elems * kernel_elems / max(out_feats, 1) / 1.0 if groups == 1 \
        else 2.0 * out_elems * kernel_elems / max(out_feats, 1)


def _collective_bytes(line: str, op: str, num_partitions: int) -> float:
    n = max(_group_size(line, num_partitions), 1)
    if n == 1:
        return 0.0
    # output type = everything between '=' and the op name
    rhs = line.split("=", 1)[1]
    type_part = rhs.split(op + "(", 1)[0]
    b = sum(_nbytes(s) for s in _all_shapes(type_part))
    if b == 0:
        return 0.0
    ring = (n - 1) / n
    if op == "all-reduce":
        return 2.0 * b * ring
    if op == "all-gather":
        return b * ring
    if op == "reduce-scatter":
        return b * (n - 1)  # input = out * n; wire = in * (n-1)/n = out*(n-1)
    if op == "all-to-all":
        return b * ring
    if op == "collective-permute":
        return float(b)
    return 0.0


def parse_hlo(hlo: str, num_partitions: int) -> dict[str, ComputationStats]:
    comps = split_computations(hlo)
    stats: dict[str, ComputationStats] = {}
    for name, lines in comps.items():
        st = ComputationStats()
        symbols: dict[str, tuple[str, tuple[int, ...]]] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            var, rhs = dm.group(1), dm.group(2)
            shape = _parse_shape(rhs)
            if shape:
                symbols[var] = shape

        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            # strip metadata/backend_config noise for opname search, keep line
            # for attribute parsing
            head = rhs.split(", metadata=")[0]
            om = _OPNAME_RE.search(head)
            if om is None:
                continue
            opname = om.group(1)

            if opname == "dot":
                st.flops += _dot_flops(line, symbols)
            elif opname == "convolution":
                st.flops += _conv_flops(line, symbols)
            elif opname in (
                "all-reduce",
                "all-gather",
                "reduce-scatter",
                "all-to-all",
                "collective-permute",
            ):
                st.collective_bytes += _collective_bytes(line, opname, num_partitions)
            elif opname == "while":
                body = re.search(r"body=%?([\w\.\-]+)", line)
                cond = re.search(r"condition=%?([\w\.\-]+)", line)
                tm = _TRIP_RE.search(line)
                if tm:
                    trips = float(tm.group(1))
                elif cond and cond.group(1) in comps:
                    trips = _trip_count(comps[cond.group(1)])
                else:
                    trips = 1.0
                if body:
                    st.calls.append((body.group(1), "while_body"))
                    st.while_trips[body.group(1)] = trips

            # call-graph edges (fusions, reduces, conditionals...)
            if opname != "while":
                for callee in _CALLED_RE.findall(line):
                    st.calls.append((callee, "plain"))

            # HBM traffic proxy: top-level materialized buffers.
            #
            # Two TPU-target adjustments (the compiled module comes from the
            # CPU backend, which materializes things a TPU would fuse):
            #  * pure data-movement fusions (convert/copy/transpose/bitcast/
            #    broadcast combinations — e.g. the f32 shadow copies of bf16
            #    KV caches that CPU dots require) are skipped: TPU MXUs eat
            #    bf16 natively and fuse converts into consumers;
            #  * dynamic-(update-)slice ops write/read only the slice, not
            #    the aliased full buffer — count 3x the smallest non-scalar
            #    operand (read-modify-write of the slice).
            if opname not in _SKIP_TRAFFIC_OPS:
                var = dm.group(1)
                var_tokens = {
                    tok
                    for tok in re.split(r"[_.]", var)
                    if tok and not tok.isdigit()
                } - _DATA_MOVEMENT_IGNORE
                if opname == "fusion" and var_tokens and var_tokens <= _DATA_MOVEMENT_CORE:
                    continue
                sliced = (
                    opname in ("dynamic-slice", "dynamic-update-slice")
                    or (opname == "fusion" and ("dynamic-update-slice" in var or "dynamic-slice" in var))
                )
                out_bytes = sum(_nbytes(s) for s in _all_shapes(rhs[: om.start()]))
                operand_bytes: list[int] = []
                ops = re.search(rf"{re.escape(opname)}\(([^)]*)\)", rhs)
                if ops:
                    for oname in _operand_names(ops.group(1)):
                        if oname in symbols:
                            operand_bytes.append(_nbytes(symbols[oname]))
                if sliced:
                    nonscalar = [b for b in operand_bytes if b > 256]
                    slice_b = min(nonscalar) if nonscalar else out_bytes
                    if opname == "dynamic-slice" or "dynamic-slice" in var:
                        slice_b = min(slice_b, out_bytes)
                    st.hbm_bytes += 3 * slice_b
                else:
                    st.hbm_bytes += out_bytes + sum(operand_bytes)
        stats[name] = st
    return stats


def _trip_count(cond_lines: list[str]) -> float:
    """Static trip count from the loop condition's compare constant."""
    consts = []
    for line in cond_lines:
        m = re.search(r"constant\((\d+)\)", line)
        if m:
            consts.append(int(m.group(1)))
    return float(max(consts)) if consts else 1.0


def aggregate(
    stats: dict[str, ComputationStats], entry: str
) -> dict[str, float]:
    """Walk the call graph from the entry computation, applying multipliers.

    FLOPs and collective bytes descend every edge (dots live inside wrapped/
    fused computations on some backends). HBM traffic descends ONLY through
    ``while`` bodies: fused computations keep their internals in registers/
    VMEM, so only top-level buffers of materializing computations count.
    """
    totals = {"flops": 0.0, "collective_bytes": 0.0, "hbm_bytes": 0.0}
    seen_stack: set[str] = set()

    def visit(name: str, mult: float, materializing: bool):
        if name not in stats or name in seen_stack:
            return
        seen_stack.add(name)
        st = stats[name]
        totals["flops"] += mult * st.flops
        totals["collective_bytes"] += mult * st.collective_bytes
        if materializing:
            totals["hbm_bytes"] += mult * st.hbm_bytes
        for callee, kind in st.calls:
            m = mult
            if kind == "while_body":
                m = mult * st.while_trips.get(callee, 1.0)
            visit(callee, m, materializing and kind == "while_body")
        seen_stack.discard(name)

    visit(entry, 1.0, True)
    return totals


def find_entry(hlo: str) -> str:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    if m:
        return m.group(1)
    raise ValueError("no ENTRY computation found")


@dataclasses.dataclass
class RooflineReport:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float | None = None
    useful_ratio: float | None = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze(
    hlo_text: str,
    *,
    num_partitions: int,
    model_flops_global: float | None = None,
) -> RooflineReport:
    """Analyze a compiled per-device HLO module."""
    stats = parse_hlo(hlo_text, num_partitions)
    entry = find_entry(hlo_text)
    totals = aggregate(stats, entry)
    compute_s = totals["flops"] / PEAK_FLOPS
    memory_s = totals["hbm_bytes"] / HBM_BW
    collective_s = totals["collective_bytes"] / ICI_BW
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    bottleneck = max(terms, key=terms.get)
    model_flops_dev = (
        model_flops_global / num_partitions if model_flops_global else None
    )
    useful = (
        model_flops_dev / totals["flops"]
        if model_flops_dev and totals["flops"] > 0
        else None
    )
    return RooflineReport(
        flops=totals["flops"],
        hbm_bytes=totals["hbm_bytes"],
        collective_bytes=totals["collective_bytes"],
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops_dev,
        useful_ratio=useful,
    )


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE; 2·N·D per fwd token)
# ---------------------------------------------------------------------------


def active_param_count(cfg) -> int:
    """Parameters touched per token (MoE: top-k experts only)."""
    from repro.models import params as P
    from repro.models.api import family_module

    defs = family_module(cfg).param_defs(cfg)
    total = P.param_count(defs)
    if cfg.family == "moe":
        import numpy as np

        flat = {}

        def count_expert(d):
            return int(np.prod(d.shape))

        import jax

        expert_params = 0
        leaves = jax.tree.leaves(
            defs, is_leaf=lambda x: isinstance(x, P.ParamDef)
        )
        for d in leaves:
            if "experts" in d.logical:
                expert_params += int(np.prod(d.shape))
        active_experts = expert_params * cfg.experts_per_token / cfg.num_experts
        total = total - expert_params + int(active_experts)
    return total


def model_flops_global(cfg, shape) -> float:
    """6ND for a train step; 2ND per generated/prefilled token."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
