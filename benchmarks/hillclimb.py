import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count=512"
    " --xla_disable_hlo_passes=all-reduce-promotion",
)

"""Perf-iteration driver for the three hillclimb cells (EXPERIMENTS.md §Perf).

    python -m benchmarks.hillclimb --cell A --variant baseline
    python -m benchmarks.hillclimb --cell A --variant baseline --diag   # top collectives/buffers

Variants toggle one hypothesis each (sharding mode, remat policy, chunk
sizes, dispatch resharding, ...). Every run prints the three roofline terms
so before/after lands directly in the §Perf log.
"""

import argparse
import dataclasses
import json
import re
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from benchmarks import roofline as R

CELLS = {
    "A": ("qwen3-moe-235b-a22b", "train_4k"),
    "B": ("qwen2-7b", "prefill_32k"),
    "C": ("gsplat", "features_1m"),
}


def lower_lm(arch, shape_name, mode, cfg_overrides):
    from repro.configs import get_config
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.models.api import SHAPES

    cfg = get_config(arch, **cfg_overrides)
    mesh = make_production_mesh()
    compiled = lower_cell(cfg, SHAPES[shape_name], mesh, mode=mode)
    return compiled, mesh, cfg


GSPLAT_N = 1_048_576


def lower_gsplat(variant_opts):
    """Cell C: the paper's feature pipeline, 1M Gaussians over 256 chips."""
    import jax.numpy as jnp

    from repro.core import RenderConfig, look_at_camera, random_gaussians
    from repro.core.pipeline import sharded_features, sharded_render
    from repro.launch.mesh import make_production_mesh

    n = GSPLAT_N
    mesh = make_production_mesh()  # (data, model) = (16, 16)
    axes = ("data", "model")  # gaussians sharded over the full mesh
    g = jax.eval_shape(lambda k: random_gaussians(k, n), jax.random.PRNGKey(0))
    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=1024, height=1024)
    config = RenderConfig(
        feature_path=variant_opts.get("feature_path", "staged")
    )
    fn = sharded_features(mesh, axes, config=config)
    with mesh:
        # reprolint: disable=retrace-hazard -- AOT lower/compile per searched
        # candidate is this tool's purpose; nothing is re-executed.
        compiled = jax.jit(fn).lower(g, cam).compile()
    return compiled, mesh, None


def analyze_gsplat_naive():
    """Paper-faithful 'Naive' for cell C: each of the 7 stages is its own
    program with HBM-resident inputs/outputs (the analogue of one kernel per
    AIE tile streaming intermediates). Terms are summed over stages."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.core import features as F
    from repro.core import look_at_camera
    from repro.launch.mesh import make_production_mesh

    n = GSPLAT_N
    mesh = make_production_mesh()
    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=1024, height=1024)
    sh_spec = NamedSharding(mesh, P(("data", "model")))

    def arr(*shape):
        return jax.ShapeDtypeStruct((n,) + shape, jnp.float32)

    stages = {
        "cov3D": (lambda q, s: F.stage_cov3d(q, s), (arr(4), arr(3))),
        "projection": (lambda p: F.stage_projection(p, cam), (arr(3),)),
        "Jacobian": (lambda pc: F.stage_jacobian(pc, cam), (arr(3),)),
        "cov2D": (lambda c6, j: F.stage_cov2d(c6, j, cam), (arr(6), arr(2, 3))),
        "cov2D_inv": (F.stage_cov2d_inv, (arr(3),)),
        "dir_vec": (lambda p: F.stage_ray_dir(p, cam), (arr(3),)),
        "color": (lambda sh, r: F.stage_color(sh, r), (arr(16, 3), arr(3))),
    }
    totals = {"flops": 0.0, "hbm_bytes": 0.0, "collective_bytes": 0.0}
    with mesh:
        for name, (fn, specs) in stages.items():
            shardings = tuple(sh_spec for _ in specs)
            # reprolint: disable=retrace-hazard -- AOT cost analysis: each
            # stage is lowered once, never executed.
            compiled = jax.jit(fn, in_shardings=shardings).lower(*specs).compile()
            rep = R.analyze(compiled.as_text(), num_partitions=mesh.devices.size)
            totals["flops"] += rep.flops
            totals["hbm_bytes"] += rep.hbm_bytes
            totals["collective_bytes"] += rep.collective_bytes
    return totals, mesh


def diag(hlo: str, num_partitions: int, top: int = 12) -> None:
    """Print the largest collective / traffic contributors with multipliers."""
    stats = R.parse_hlo(hlo, num_partitions)
    comps = R.split_computations(hlo)
    entry = R.find_entry(hlo)

    mults: dict[str, float] = {}

    def visit(name, mult):
        if name not in stats:
            return
        mults[name] = mults.get(name, 0) + mult
        for callee, kind in stats[name].calls:
            m = mult * (
                stats[name].while_trips.get(callee, 1.0)
                if kind == "while_body"
                else 1.0
            )
            visit(callee, m)

    visit(entry, 1.0)

    rows = []
    for cname, lines in comps.items():
        mult = mults.get(cname, 0)
        if mult == 0:
            continue
        for line in lines:
            m = re.search(
                r"%([\w\.\-]+) = .*?(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(",
                line,
            )
            if m:
                b = R._collective_bytes(line, m.group(2), num_partitions)
                shape = R._SHAPE_RE.search(line.split("=", 1)[1])
                rows.append(
                    (b * mult, m.group(2), shape.group(0) if shape else "?", cname, mult)
                )
    rows.sort(key=lambda r: -r[0])
    print("top collectives (bytes x trips):")
    for b, op, shape, cname, mult in rows[:top]:
        print(f"  {b/1e9:8.2f} GB  {op:20s} {shape:28s} x{mult:<5.0f} in {cname[:40]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--diag", action="store_true")
    args = ap.parse_args()

    arch, shape_name = CELLS[args.cell]

    # variant registry: (sharding mode, config overrides, gsplat opts)
    VARIANTS = {
        # --- cell A (MoE train, collective-bound) ---
        "baseline": ("fsdp_sp", {}, {}),
        "tp_mode": ("tensor_parallel", {}, {}),
        "remat_dots": ("fsdp_sp", {"remat": "dots"}, {}),
        "cap1.0": ("fsdp_sp", {"capacity_factor": 1.0}, {}),
        # --- cell B (dense prefill, memory-bound) ---
        "chunk2k": ("fsdp_sp", {"attn_chunk": 2048}, {}),
        "chunk512": ("fsdp_sp", {"attn_chunk": 512}, {}),
        "remat_none": ("fsdp_sp", {"remat": "none"}, {}),
        # --- cell C (gsplat pipeline) ---
        "naive": (None, {}, {}),  # 7 stage-at-a-time programs (paper Naive)
        "staged": (None, {}, {"feature_path": "staged"}),
        "fused": (None, {}, {"feature_path": "fused"}),
    }
    mode, overrides, gopts = VARIANTS[args.variant]

    t0 = time.time()
    if args.cell == "C" and args.variant == "naive":
        totals, mesh = analyze_gsplat_naive()
        n_dev = mesh.devices.size
        per_g = totals["hbm_bytes"] / (GSPLAT_N / n_dev)
        print(
            json.dumps(
                {
                    "cell": "C",
                    "variant": "naive(7-stage-streaming)",
                    "memory_s": totals["hbm_bytes"] / R.HBM_BW,
                    "hbm_bytes_per_gaussian": per_g,
                    "tput_GBps_per_chip": 236.0 * R.HBM_BW / per_g / 1e9,
                    "compile_s": round(time.time() - t0, 1),
                }
            )
        )
        return
    if args.cell == "C":
        compiled, mesh, cfg = lower_gsplat(gopts)
        model_flops = None
    else:
        compiled, mesh, cfg = lower_lm(arch, shape_name, mode, overrides)
        from repro.models.api import SHAPES

        model_flops = R.model_flops_global(cfg, SHAPES[shape_name])

    n_dev = mesh.devices.size
    hlo = compiled.as_text()
    rep = R.analyze(hlo, num_partitions=n_dev, model_flops_global=model_flops)
    print(
        json.dumps(
            {
                "cell": args.cell,
                "variant": args.variant,
                "compute_s": rep.compute_s,
                "memory_s": rep.memory_s,
                "collective_s": rep.collective_s,
                "bottleneck": rep.bottleneck,
                "useful_ratio": rep.useful_ratio,
                "compile_s": round(time.time() - t0, 1),
            },
            indent=1,
        )
    )
    if args.diag:
        diag(hlo, n_dev)


if __name__ == "__main__":
    main()
