"""Hierarchical culling throughput: SceneTree + frustum culling vs full-scene
rendering at 100k–1M Gaussians.

Every pre-PR-5 render path touches all N Gaussians per camera; the scene
subsystem (``repro.core.scene``) gathers only the frustum-visible chunks, so
per-camera cost tracks *visible* scene size. This benchmark measures that
trade on uniform and clustered scenes with cameras placed **inside** the
cloud (the unbounded-capture serving shape: any one view sees a fraction of
the scene):

* sequential req/s of the uncull path (``render_jit`` on the raw cloud)
  vs the culled path (``render_jit`` on the ``SceneTree``) at a
  conservative ``visible_capacity`` (>= the max visible-chunk count across
  the camera orbit, so nothing is ever dropped);
* pixel equality of the two (conservative culling only removes Gaussians
  the rasterizer's support contract already excludes, so the tile lists —
  and therefore the blended images — are identical);
* the distance-LOD variant (``lod_thresholds``): per-chunk SH degree
  3/1/0, reported with its per-band chunk counts;
* visible-fraction stats per scene (the number the speedup should track).

``--tiny`` is the CI smoke: a small clustered scene where <50% of chunks
are visible; asserts culled >= uncull req/s and culled == uncull images,
and drives a cull-configured RenderServer end to end in both scheduler
modes (``continuous`` and ``microbatch``).

    PYTHONPATH=src python -m benchmarks.bench_culling [--tiny]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import (
    RenderConfig,
    build_scene_tree,
    clustered_gaussians,
    look_at_camera,
    random_gaussians,
    visibility_stats,
)
from repro.core.render import render_jit

IMAGE_SIZE = 256
CAMERAS = 2
ITERS = 2
LEAF_SIZE = 256
# (scene kind, sizes): uniform capped at 500k to bound bench wall time.
SWEEP = (
    ("uniform", (100_000, 500_000)),
    ("clustered", (100_000, 500_000, 1_000_000)),
)
# Chunk distance is conservative (to the bounding-sphere surface), and the
# 3-sigma-padded Morton chunks of these scenes have ~0.5-1.0 radii, so
# visible-chunk distances land in [0, ~0.8] — thresholds chosen to split
# the orbit's visible set across all three SH bands.
LOD_THRESHOLDS = (0.2, 0.5)

TINY_IMAGE_SIZE = 96
TINY_N = 20_000
TINY_LEAF = 128


def make_scene(kind: str, n: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    if kind == "uniform":
        return random_gaussians(key, n, extent=2.0)
    return clustered_gaussians(key, n, num_clusters=12, extent=2.0)


def inside_cameras(num: int, size: int, radius: float = 0.8):
    """Cameras inside the cloud looking outward — each view covers one
    frustum's worth of an unbounded scene, not the whole cloud."""
    cams = []
    for i in range(num):
        th = 2.0 * np.pi * i / num
        eye = (radius * np.cos(th), 0.2, radius * np.sin(th))
        tgt = (3 * radius * np.cos(th), 0.2, 3 * radius * np.sin(th))
        cams.append(look_at_camera(eye, tgt, width=size, height=size))
    return cams


def _seq_req_s(model, cams, cfg, iters: int) -> tuple[float, list]:
    """Sequential per-request throughput; returns (req/s, last images)."""
    render_jit(model, cams[0], cfg).block_until_ready()  # compile+warm
    walls, imgs = [], []
    for _ in range(iters):
        imgs = []
        t0 = time.perf_counter()
        for cam in cams:
            imgs.append(render_jit(model, cam, cfg))
        jax.block_until_ready(imgs)
        walls.append(time.perf_counter() - t0)
    walls.sort()
    return len(cams) / walls[len(walls) // 2], imgs


def bench_scene(
    kind: str,
    n: int,
    *,
    image_size: int,
    leaf_size: int,
    iters: int,
) -> dict:
    g = make_scene(kind, n)
    t0 = time.perf_counter()
    tree = jax.block_until_ready(build_scene_tree(g, leaf_size=leaf_size))
    build_s = time.perf_counter() - t0
    cams = inside_cameras(CAMERAS, image_size)

    cfg = RenderConfig(raster_path="binned")
    probe = cfg.replace(cull=True, lod_thresholds=LOD_THRESHOLDS)
    stats = [visibility_stats(tree, c, probe) for c in cams]
    vis_frac = [s["visible_fraction"] for s in stats]
    # Conservative static capacity: every visible chunk of every camera
    # fits, so culling never drops content and images must match exactly.
    capacity = max(s["num_visible"] for s in stats)
    cfg_cull = cfg.replace(cull=True, visible_capacity=capacity)
    cfg_lod = cfg_cull.replace(lod_thresholds=LOD_THRESHOLDS)

    # Uncull baseline renders the *resident* (Morton-permuted) cloud — the
    # same model the culled path serves, same N, same cost as the raw
    # order. Comparing against the raw cloud instead would differ at f32
    # depth *ties* (order-dependent blending), not because culling drops
    # content.
    uncull_req_s, base_imgs = _seq_req_s(tree.gaussians, cams, cfg, iters)
    culled_req_s, cull_imgs = _seq_req_s(tree, cams, cfg_cull, iters)
    lod_req_s, lod_imgs = _seq_req_s(tree, cams, cfg_lod, iters)

    eq_err = max(
        float(jax.numpy.abs(a - b).max())
        for a, b in zip(base_imgs, cull_imgs)
    )
    lod_err = max(
        float(jax.numpy.abs(a - b).max())
        for a, b in zip(base_imgs, lod_imgs)
    )

    tag = f"culling/{kind}_{n}"
    emit(
        f"{tag}_culled_req_s",
        1e6 / culled_req_s,
        f"{culled_req_s / uncull_req_s:.2f}x_uncull_vis{np.mean(vis_frac):.0%}",
    )
    return {
        "gaussians": n,
        "image_size": image_size,
        "leaf_size": leaf_size,
        "num_chunks": tree.num_chunks,
        "tree_build_s": build_s,
        "visible_fraction_mean": float(np.mean(vis_frac)),
        "visible_capacity": capacity,
        "uncull_req_s": uncull_req_s,
        "culled_req_s": culled_req_s,
        "culled_speedup": culled_req_s / uncull_req_s,
        "culled_max_err": eq_err,
        "lod_req_s": lod_req_s,
        "lod_speedup": lod_req_s / uncull_req_s,
        "lod_max_err_vs_full_sh": lod_err,
        "lod_degree_counts": stats[0]["degree_counts"],
    }


def _tiny_serving(tree, cfg_cull, cams) -> dict:
    """Drive a cull-configured RenderServer in both scheduler modes."""
    from repro.serve import RenderServer, replay_schedule

    base = [
        np.asarray(render_jit(tree.gaussians, c, cfg_cull.replace(cull=False)))
        for c in cams
    ]
    out = {}
    size = cams[0].width
    for mode in ("continuous", "microbatch"):
        server = RenderServer(
            tree, cfg_cull, width=size, height=size, max_batch=4, mode=mode
        )
        server.warmup(cams[0])
        with server:
            results, wall = replay_schedule(
                server.submit, cams * 3, np.zeros(len(cams) * 3)
            )
        err = max(
            float(np.abs(r.image - base[i % len(cams)]).max())
            for i, r in enumerate(results)
        )
        out[mode] = {"req_s": len(results) / wall, "max_err_vs_uncull": err}
        emit(
            f"culling/serving_{mode}_req_s",
            1e6 / out[mode]["req_s"],
            f"err{err:.1e}",
        )
        assert err <= 1e-5, (
            f"culled {mode} serving diverged from uncull render: {err}"
        )
    return out


def main(argv: tuple[str, ...] | list[str] = ()) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny",
        action="store_true",
        help="CI smoke: small clustered scene, asserts culled >= uncull "
        "req/s with <50%% of chunks visible + cull-serving in both modes",
    )
    args = ap.parse_args(list(argv))

    if args.tiny:
        n, size, leaf = TINY_N, TINY_IMAGE_SIZE, TINY_LEAF
        entry = bench_scene(
            "clustered", n, image_size=size, leaf_size=leaf, iters=1
        )
        metrics = {"clustered": {str(n): entry}}

        assert entry["visible_fraction_mean"] < 0.5, (
            "smoke scene must have <50% of chunks visible, got "
            f"{entry['visible_fraction_mean']:.0%}"
        )
        assert entry["culled_max_err"] <= 1e-5, entry
        assert entry["culled_req_s"] >= entry["uncull_req_s"], (
            f"culled rendering slower than uncull: {entry}"
        )

        tree = build_scene_tree(make_scene("clustered", n), leaf_size=leaf)
        cfg_cull = RenderConfig(
            raster_path="binned",
            cull=True,
            visible_capacity=entry["visible_capacity"],
        )
        metrics["serving"] = _tiny_serving(
            tree, cfg_cull, inside_cameras(CAMERAS, size)
        )
        print(
            f"# tiny smoke OK: culled {entry['culled_speedup']:.2f}x uncull "
            f"at {entry['visible_fraction_mean']:.0%} visible chunks, "
            f"serving continuous {metrics['serving']['continuous']['req_s']:.2f} "
            f"req/s / microbatch "
            f"{metrics['serving']['microbatch']['req_s']:.2f} req/s"
        )
        return metrics

    metrics: dict = {}
    for kind, sizes in SWEEP:
        metrics[kind] = {}
        for n in sizes:
            metrics[kind][str(n)] = bench_scene(
                kind,
                n,
                image_size=IMAGE_SIZE,
                leaf_size=LEAF_SIZE,
                iters=ITERS,
            )
    return metrics


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
