"""Serving throughput: batched rendering, and continuous vs micro-batching.

The paper's 226x claim is a *throughput* number — a trained model served
against a camera stream. This benchmark measures that trade on our
substrate in two layers:

* req/s of the batched render path (``render_batch`` — one executable,
  pooled load-balanced tiles) against the sequential per-request baseline
  (one ``render_jit`` dispatch per camera), across batch sizes and raster
  paths;
* the **scheduler sweep**: the continuous-batching RenderServer (persistent
  slot table, immediate refill, pipelined dispatch) against the
  micro-batching window baseline, under *identical* open-loop Poisson
  arrival schedules at rates from below saturation to a full burst.
  Continuous batching must win (or tie) req/s at every rate and cut p95
  latency at high load — that is the whole point of not draining windows.

Every speedup is reported next to its occupancy/latency context — a
throughput number without its batching regime is not a result.

    PYTHONPATH=src python -m benchmarks.bench_serving [--tiny]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import RenderConfig, orbit_cameras, random_gaussians, stack_cameras
from repro.core.multicam import render_batch_jit
from repro.core.render import render_jit
from repro.obs import Registry, SLOMonitor, SLOTargets, serve_metrics
from repro.serve import RenderServer, replay_schedule

N = 8_192
SIZE = 128
REQUESTS = 16
BATCH_SIZES = (1, 2, 4, 8)

# Tiny = CI smoke. Big enough that blending dominates a step (4k G, 96^2),
# long enough (24 requests) to average per-render noise, and WIDE enough
# (8 slots) that partial occupancy is the steady state — where
# micro-batching blends its copied-camera padding at full price and the
# continuous scheduler's masked slots skip it. Narrower/smaller smokes put
# the two schedulers within container noise of each other.
TINY_N = 4_096
TINY_SIZE = 96
TINY_REQUESTS = 24
TINY_BATCH_SIZES = (1, 8)


def _median(samples: list[float]) -> float:
    samples = sorted(samples)
    return samples[len(samples) // 2]


def _seq_req_s(model, cams, cfg, iters: int) -> tuple[float, np.ndarray]:
    """Sequential baseline: one dispatch per request. Returns (req/s, lat ms)."""
    render_jit(model, cams[0], cfg).block_until_ready()  # warmup/compile
    walls, lat = [], []
    for _ in range(iters):
        lat = []
        t0 = time.perf_counter()
        for cam in cams:
            t_req = time.perf_counter()
            render_jit(model, cam, cfg).block_until_ready()
            lat.append((time.perf_counter() - t_req) * 1e3)
        walls.append(time.perf_counter() - t0)
    return len(cams) / _median(walls), np.asarray(lat)


def _batched_req_s(model, cams, cfg, batch_size: int, iters: int) -> float:
    """Closed-loop batched throughput at a fixed batch size."""
    if len(cams) % batch_size != 0:
        raise ValueError(
            f"{len(cams)} requests do not divide into batches of "
            f"{batch_size}; the comparison against the sequential baseline "
            "(which renders every camera) would silently drop the remainder"
        )
    groups = [
        stack_cameras(cams[i : i + batch_size])
        for i in range(0, len(cams) - batch_size + 1, batch_size)
    ]
    render_batch_jit(model, groups[0], cfg).block_until_ready()  # warmup
    walls = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for gb in groups:
            render_batch_jit(model, gb, cfg).block_until_ready()
        walls.append(time.perf_counter() - t0)
    return len(groups) * batch_size / _median(walls)


def _stream_run(
    model, cams, cfg, mode: str, gaps: np.ndarray, max_batch: int,
    max_wait_ms: float = 20.0,
) -> dict:
    """One open-loop arrival stream against a RenderServer.

    ``gaps`` is the inter-arrival schedule in seconds (zeros = burst); the
    same schedule is replayed against every mode, so a continuous-vs-micro
    comparison sees identical offered load, not two Poisson draws.
    """
    size = cams[0].width
    server = RenderServer(
        model, cfg, width=size, height=size, max_batch=max_batch,
        max_wait_ms=max_wait_ms, mode=mode,
    )
    compile_ms = server.warmup(cams[0])
    with server:
        results, wall = replay_schedule(server.submit, cams, gaps)
    stats = server.stats()
    lat = np.asarray([r.latency_ms for r in results])
    return {
        "mode": mode,
        "req_s": len(cams) / wall,
        "compile_ms": compile_ms,
        "occupancy": stats["occupancy"],
        "mean_batch_size": stats["mean_batch_size"],
        "latency_ms_p50": float(np.percentile(lat, 50)),
        "latency_ms_p95": float(np.percentile(lat, 95)),
    }


def _server_run(model, cams, cfg, max_batch: int, mode: str = "continuous") -> dict:
    """End-to-end RenderServer pass (closed loop): occupancy + latency."""
    return _stream_run(
        model, cams, cfg, mode, np.zeros(len(cams)), max_batch
    )


def _scheduler_sweep(
    model, cams, cfg, max_batch: int, rate_multipliers, capacity_req_s: float,
    seed: int = 0, streams: int = 1,
) -> dict:
    """Continuous vs micro-batching under identical arrival schedules.

    Rates are relative to the measured closed-loop batched capacity, so the
    sweep spans under-saturation (windows mostly partial — micro-batching
    pays max_wait_ms to fill them) through over-saturation (queues never
    drain — scheduling overhead is the whole difference), plus a burst
    (``rate 0``: the entire offered load arrives at t=0).

    ``streams`` independent schedule draws are replayed against *both*
    modes and the reported req/s aggregates over them: a single Poisson
    draw can quantize into batches that luck one scheduler ahead by a few
    percent, which a CI assert must not hang on.
    """
    rng = np.random.default_rng(seed)
    sweep: dict = {}
    for mult in rate_multipliers:
        rate = capacity_req_s * mult if mult > 0 else 0.0
        label = f"{mult:g}x_capacity" if mult > 0 else "burst"
        walls = {"microbatch": 0.0, "continuous": 0.0}
        runs = {}
        for s in range(max(1, streams)):
            gaps = (
                rng.exponential(1.0 / rate, size=len(cams))
                if rate > 0
                else np.zeros(len(cams))
            )
            # Alternate which mode runs first: a machine-wide slowdown
            # ramping up mid-sweep must not land systematically on one side
            # of the req/s comparison.
            order = ("microbatch", "continuous")
            if s % 2:
                order = order[::-1]
            for mode in order:
                r = _stream_run(model, cams, cfg, mode, gaps, max_batch)
                walls[mode] += len(cams) / r["req_s"]
                runs[mode] = r  # latency/occupancy context: last stream
        for mode, r in runs.items():
            r["req_s"] = max(1, streams) * len(cams) / walls[mode]
        micro, cont = runs["microbatch"], runs["continuous"]
        sweep[label] = {
            "arrival_req_s": rate,
            "streams": max(1, streams),
            "microbatch": micro,
            "continuous": cont,
            "continuous_speedup": cont["req_s"] / micro["req_s"],
        }
        emit(
            f"serving/sched_{label}_continuous_req_s",
            1e6 / cont["req_s"],
            f"{cont['req_s']:.2f}req_s_{cont['req_s'] / micro['req_s']:.2f}x_micro",
        )
    return sweep


def _burst_images(model, cams, cfg, max_batch: int, slo=None):
    """One full burst through a continuous server; returns (images, wall_s).

    Identical offered load with and without ``slo`` — the monitored run
    must serve the same frames at (close to) the same rate.
    """
    size = cams[0].width
    server = RenderServer(
        model, cfg, width=size, height=size, max_batch=max_batch, slo=slo,
    )
    server.warmup(cams[0])
    with server:
        t0 = time.perf_counter()
        futs = [server.submit(cam) for cam in cams]
        images = [f.result().image for f in futs]
        wall = time.perf_counter() - t0
    return images, wall


def _slo_smoke(model, cams, cfg, max_batch: int) -> dict:
    """Live SLO layer under a > capacity burst, endpoints polled mid-load.

    The whole request set arrives at t=0 against ``max_batch`` slots with a
    queue-depth target far below the burst size, so the monitor *must*
    pass through ``overloaded`` while the queue drains (``/healthz`` 503)
    and recover to ``ok`` after ``clear_s`` of calm. A twin unmonitored
    burst pins the overhead: identical images, comparable wall clock.
    """
    import json as _json
    import urllib.error
    import urllib.request

    base_images, base_wall = _burst_images(model, cams, cfg, max_batch)

    reg = Registry()
    monitor = SLOMonitor(
        SLOTargets(
            max_queue_depth=float(max_batch // 2),
            window_s=30.0,
            trip_s=0.0,
            clear_s=0.3,
        ),
        registry=reg,
        mode="continuous",
    )
    http = serve_metrics(reg, slo=monitor)
    states_seen: set[str] = set()
    healthz_codes: set[int] = set()

    def poll() -> None:
        req = urllib.request.Request(f"http://127.0.0.1:{http.port}/healthz")
        try:
            with urllib.request.urlopen(req, timeout=5) as r:
                healthz_codes.add(r.status)
        except urllib.error.HTTPError as e:
            healthz_codes.add(e.code)
        with urllib.request.urlopen(
            f"http://127.0.0.1:{http.port}/slo", timeout=5
        ) as r:
            states_seen.add(_json.loads(r.read())["state"])

    size = cams[0].width
    server = RenderServer(
        model, cfg, width=size, height=size, max_batch=max_batch,
        slo=monitor,
    )
    server.warmup(cams[0])
    with server:
        t0 = time.perf_counter()
        futs = [server.submit(cam) for cam in cams]
        poll()  # mid-burst: the queue is deep right now
        images = [f.result().image for f in futs]
        wall = time.perf_counter() - t0
        poll()
        # Drained: wait out clear_s (+ margin) for the recovery transition.
        deadline = time.perf_counter() + 5.0
        while monitor.evaluate() != "ok" and time.perf_counter() < deadline:
            time.sleep(0.05)
        poll()
    http.shutdown()

    identical = len(base_images) == len(images) and all(
        np.array_equal(a, b) for a, b in zip(base_images, images)
    )
    return {
        "req_s": len(cams) / wall,
        "req_s_unmonitored": len(cams) / base_wall,
        "overhead_ratio": base_wall / wall,  # ~1.0 = monitor is free
        "states_seen": sorted(states_seen),
        "healthz_codes": sorted(healthz_codes),
        "transitions": monitor.transitions(),
        "final_state": monitor.state,
        "images_identical": identical,
    }


def main(argv: tuple[str, ...] | list[str] = ()) -> dict:
    """Run the serving benchmarks; returns machine-readable metrics
    (``benchmarks/run.py`` folds them into ``BENCH_PR3.json``)."""
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny",
        action="store_true",
        help="CI smoke: small scene, binned only, asserts batched "
        "throughput >= sequential",
    )
    args = ap.parse_args(list(argv))

    n = TINY_N if args.tiny else N
    size = TINY_SIZE if args.tiny else SIZE
    requests = TINY_REQUESTS if args.tiny else REQUESTS
    batch_sizes = TINY_BATCH_SIZES if args.tiny else BATCH_SIZES
    # 3 samples -> a true median even on a noisy shared runner; the tiny
    # smoke keeps CI in seconds with 1.
    iters = 1 if args.tiny else 3
    paths = ("binned",) if args.tiny else ("binned", "pallas_binned")

    model = random_gaussians(jax.random.PRNGKey(0), n, extent=1.5)
    cams = orbit_cameras(requests, radius=5.0, width=size, height=size)

    metrics: dict = {
        "gaussians": n,
        "image_size": size,
        "requests": requests,
        "paths": {},
    }

    for path in paths:
        cfg = RenderConfig(raster_path=path)
        # The interpret-mode Pallas path is seconds per frame on CPU; keep
        # its sweep to the largest batch so the full bench stays in minutes.
        sizes = batch_sizes if path == "binned" else (batch_sizes[-1],)
        p_reqs = requests if path == "binned" else max(sizes[-1], 4)
        p_cams = cams[:p_reqs]
        p_iters = iters if path == "binned" else 1

        seq_req_s, seq_lat = _seq_req_s(model, p_cams, cfg, p_iters)
        emit(
            f"serving/{path}_sequential_req_s",
            1e6 / seq_req_s,
            f"{seq_req_s:.2f}req_s",
        )

        batched = {}
        for bs in sizes:
            req_s = _batched_req_s(model, p_cams, cfg, bs, p_iters)
            batched[str(bs)] = {
                "req_s": req_s,
                "speedup_vs_sequential": req_s / seq_req_s,
            }
            emit(
                f"serving/{path}_batched{bs}_req_s",
                1e6 / req_s,
                f"{req_s:.2f}req_s_{req_s / seq_req_s:.2f}x",
            )

        metrics["paths"][path] = {
            "sequential_req_s": seq_req_s,
            "sequential_latency_ms_p50": float(np.percentile(seq_lat, 50)),
            "sequential_latency_ms_p95": float(np.percentile(seq_lat, 95)),
            "batched": batched,
        }

    # End-to-end server pass (binned, largest batch, continuous): the
    # occupancy and latency-percentile context for the numbers above.
    server_cfg = RenderConfig(raster_path="binned")
    srv = _server_run(model, cams, server_cfg, max_batch=batch_sizes[-1])
    metrics["server"] = srv
    emit(
        "serving/server_req_s",
        1e6 / srv["req_s"],
        f"{srv['req_s']:.2f}req_s_occ{srv['occupancy']:.0%}",
    )
    emit(
        "serving/server_latency_p50",
        srv["latency_ms_p50"] * 1e3,
        f"p95={srv['latency_ms_p95']:.1f}ms",
    )

    # Scheduler sweep: continuous vs micro-batching at identical offered
    # load, rates anchored to the measured closed-loop batched capacity.
    capacity = metrics["paths"]["binned"]["batched"][str(batch_sizes[-1])]["req_s"]
    # Tiny sweeps one clearly-above-saturation rate: with queues formed,
    # both schedulers run high occupancy and the margin is the structural
    # one the smoke asserts on (pipelined dispatch-before-harvest + masked
    # instead of copied-camera padding on the partial tail), measured at
    # ~1.1x and stable across trials. At/below saturation the two
    # schedulers' batch quantization makes the comparison a coin flip on a
    # noisy 2-core runner — the full sweep covers those regimes.
    multipliers = (1.5,) if args.tiny else (0.75, 1.5, 3.0, 0.0)
    metrics["scheduler_sweep"] = _scheduler_sweep(
        model,
        cams,
        server_cfg,
        max_batch=batch_sizes[-1],
        rate_multipliers=multipliers,
        capacity_req_s=capacity,
        streams=3 if args.tiny else 1,
    )

    # Live SLO layer: monitored vs unmonitored burst + endpoint polling.
    metrics["slo"] = slo = _slo_smoke(
        model, cams, server_cfg, max_batch=batch_sizes[-1]
    )
    emit(
        "serving/slo_monitored_req_s",
        1e6 / slo["req_s"],
        f"{slo['req_s']:.2f}req_s_states_{'_'.join(slo['states_seen'])}",
    )

    if args.tiny:
        # The burst (3x the slot table) must visibly overload, serve 503 on
        # /healthz while it lasts, and recover once drained; the monitor
        # must not change what is served or (materially) how fast.
        assert "overloaded" in slo["states_seen"], slo
        assert 503 in slo["healthz_codes"], slo
        assert 200 in slo["healthz_codes"], slo
        assert slo["final_state"] == "ok", slo
        assert slo["images_identical"], "SLO monitor changed served images"
        assert slo["overhead_ratio"] >= 0.6, (
            f"SLO monitor cost too much serving throughput: {slo}"
        )
        top = metrics["paths"]["binned"]["batched"][str(batch_sizes[-1])]
        # Re-baselined with bin_gaussians' select="sort" default (PR 4):
        # the flip sped the *sequential* baseline up ~3.5x on binning, so at
        # this tiny scale batched ~= sequential instead of the old >= 1.0
        # margin (batching still wins at the full bench scale). The floor
        # pins "batching never catastrophically regresses"; the continuous
        # >= micro assert below is the scheduler contract.
        assert top["speedup_vs_sequential"] >= 0.8, (
            f"batched serving far slower than sequential: {metrics['paths']}"
        )
        assert 0.0 < srv["occupancy"] <= 1.0, srv
        # Even with 3 alternating-order streams, a single sweep's
        # continuous-vs-micro ratio jitters a few percent either side of
        # parity on a 2-core runner (observed 0.98–1.15x at this scale).
        # The inline smoke only pins "not catastrophically slower"; the
        # statistical contract — median across --trials runs >= 0.9 with a
        # MAD-sized noise margin — is the perfguard budget
        # serving-continuous-vs-micro (pyproject [tool.perfguard]).
        for label, entry in metrics["scheduler_sweep"].items():
            assert entry["continuous_speedup"] >= 0.85, (
                f"continuous batching far slower than micro-batching at "
                f"{label}: {entry}"
            )
        print(
            f"# tiny smoke OK: batched {top['speedup_vs_sequential']:.2f}x "
            f"sequential at batch {batch_sizes[-1]}, "
            f"server occupancy {srv['occupancy']:.0%}, continuous "
            + ", ".join(
                f"{e['continuous_speedup']:.2f}x micro at {label}"
                for label, e in metrics["scheduler_sweep"].items()
            )
            + f"; slo states {slo['states_seen']} "
            f"(overhead {slo['overhead_ratio']:.2f}x)"
        )

    return metrics


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
