"""Serving throughput: batched multi-camera serving vs the sequential path.

The paper's 226x claim is a *throughput* number — a trained model served
against a camera stream. This benchmark measures exactly that trade on our
substrate: req/s of the batched render path (``render_batch`` — one
executable, pooled load-balanced tiles) against the sequential per-request
baseline (one ``render_jit`` dispatch per camera), across batch sizes and
raster paths, plus an end-to-end :class:`repro.serve.RenderServer` run that
reports micro-batch occupancy and request latency percentiles.

Every speedup is reported next to its occupancy/latency context — a
throughput number without its batching regime is not a result.

    PYTHONPATH=src python -m benchmarks.bench_serving [--tiny]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import RenderConfig, orbit_cameras, random_gaussians, stack_cameras
from repro.core.multicam import render_batch_jit
from repro.core.render import render_jit
from repro.serve import RenderServer

N = 8_192
SIZE = 128
REQUESTS = 16
BATCH_SIZES = (1, 2, 4, 8)

TINY_N = 2_048
TINY_SIZE = 64
TINY_REQUESTS = 8
TINY_BATCH_SIZES = (1, 4)


def _median(samples: list[float]) -> float:
    samples = sorted(samples)
    return samples[len(samples) // 2]


def _seq_req_s(model, cams, cfg, iters: int) -> tuple[float, np.ndarray]:
    """Sequential baseline: one dispatch per request. Returns (req/s, lat ms)."""
    render_jit(model, cams[0], cfg).block_until_ready()  # warmup/compile
    walls, lat = [], []
    for _ in range(iters):
        lat = []
        t0 = time.perf_counter()
        for cam in cams:
            t_req = time.perf_counter()
            render_jit(model, cam, cfg).block_until_ready()
            lat.append((time.perf_counter() - t_req) * 1e3)
        walls.append(time.perf_counter() - t0)
    return len(cams) / _median(walls), np.asarray(lat)


def _batched_req_s(model, cams, cfg, batch_size: int, iters: int) -> float:
    """Closed-loop batched throughput at a fixed batch size."""
    if len(cams) % batch_size != 0:
        raise ValueError(
            f"{len(cams)} requests do not divide into batches of "
            f"{batch_size}; the comparison against the sequential baseline "
            "(which renders every camera) would silently drop the remainder"
        )
    groups = [
        stack_cameras(cams[i : i + batch_size])
        for i in range(0, len(cams) - batch_size + 1, batch_size)
    ]
    render_batch_jit(model, groups[0], cfg).block_until_ready()  # warmup
    walls = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for gb in groups:
            render_batch_jit(model, gb, cfg).block_until_ready()
        walls.append(time.perf_counter() - t0)
    return len(groups) * batch_size / _median(walls)


def _server_run(model, cams, cfg, max_batch: int) -> dict:
    """End-to-end RenderServer pass (closed loop): occupancy + latency."""
    size = cams[0].width
    server = RenderServer(
        model, cfg, width=size, height=size, max_batch=max_batch, max_wait_ms=20.0
    )
    compile_ms = server.warmup(cams[0])
    with server:
        t0 = time.perf_counter()
        futures = [server.submit(c) for c in cams]
        results = [f.result() for f in futures]
        wall = time.perf_counter() - t0
    stats = server.stats()
    lat = np.asarray([r.latency_ms for r in results])
    return {
        "req_s": len(cams) / wall,
        "compile_ms": compile_ms,
        "occupancy": stats["occupancy"],
        "mean_batch_size": stats["mean_batch_size"],
        "latency_ms_p50": float(np.percentile(lat, 50)),
        "latency_ms_p95": float(np.percentile(lat, 95)),
    }


def main(argv: tuple[str, ...] | list[str] = ()) -> dict:
    """Run the serving benchmarks; returns machine-readable metrics
    (``benchmarks/run.py`` folds them into ``BENCH_PR3.json``)."""
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny",
        action="store_true",
        help="CI smoke: small scene, binned only, asserts batched "
        "throughput >= sequential",
    )
    args = ap.parse_args(list(argv))

    n = TINY_N if args.tiny else N
    size = TINY_SIZE if args.tiny else SIZE
    requests = TINY_REQUESTS if args.tiny else REQUESTS
    batch_sizes = TINY_BATCH_SIZES if args.tiny else BATCH_SIZES
    # 3 samples -> a true median even on a noisy shared runner; the tiny
    # smoke keeps CI in seconds with 1.
    iters = 1 if args.tiny else 3
    paths = ("binned",) if args.tiny else ("binned", "pallas_binned")

    model = random_gaussians(jax.random.PRNGKey(0), n, extent=1.5)
    cams = orbit_cameras(requests, radius=5.0, width=size, height=size)

    metrics: dict = {
        "gaussians": n,
        "image_size": size,
        "requests": requests,
        "paths": {},
    }

    for path in paths:
        cfg = RenderConfig(raster_path=path)
        # The interpret-mode Pallas path is seconds per frame on CPU; keep
        # its sweep to the largest batch so the full bench stays in minutes.
        sizes = batch_sizes if path == "binned" else (batch_sizes[-1],)
        p_reqs = requests if path == "binned" else max(sizes[-1], 4)
        p_cams = cams[:p_reqs]
        p_iters = iters if path == "binned" else 1

        seq_req_s, seq_lat = _seq_req_s(model, p_cams, cfg, p_iters)
        emit(
            f"serving/{path}_sequential_req_s",
            1e6 / seq_req_s,
            f"{seq_req_s:.2f}req_s",
        )

        batched = {}
        for bs in sizes:
            req_s = _batched_req_s(model, p_cams, cfg, bs, p_iters)
            batched[str(bs)] = {
                "req_s": req_s,
                "speedup_vs_sequential": req_s / seq_req_s,
            }
            emit(
                f"serving/{path}_batched{bs}_req_s",
                1e6 / req_s,
                f"{req_s:.2f}req_s_{req_s / seq_req_s:.2f}x",
            )

        metrics["paths"][path] = {
            "sequential_req_s": seq_req_s,
            "sequential_latency_ms_p50": float(np.percentile(seq_lat, 50)),
            "sequential_latency_ms_p95": float(np.percentile(seq_lat, 95)),
            "batched": batched,
        }

    # End-to-end server pass (binned, largest batch): the occupancy and
    # latency-percentile context for the throughput numbers above.
    server_cfg = RenderConfig(raster_path="binned")
    srv = _server_run(model, cams, server_cfg, max_batch=batch_sizes[-1])
    metrics["server"] = srv
    emit(
        "serving/server_req_s",
        1e6 / srv["req_s"],
        f"{srv['req_s']:.2f}req_s_occ{srv['occupancy']:.0%}",
    )
    emit(
        "serving/server_latency_p50",
        srv["latency_ms_p50"] * 1e3,
        f"p95={srv['latency_ms_p95']:.1f}ms",
    )

    if args.tiny:
        top = metrics["paths"]["binned"]["batched"][str(batch_sizes[-1])]
        assert top["speedup_vs_sequential"] >= 1.0, (
            f"batched serving slower than sequential: {metrics['paths']}"
        )
        assert 0.0 < srv["occupancy"] <= 1.0, srv
        print(
            f"# tiny smoke OK: batched {top['speedup_vs_sequential']:.2f}x "
            f"sequential at batch {batch_sizes[-1]}, "
            f"server occupancy {srv['occupancy']:.0%}"
        )

    return metrics


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
