"""Benchmark aggregator. One section per paper table/figure + substrate.

Prints ``name,us_per_call,derived`` CSV lines (the repo-wide contract) and
writes ``BENCH_PR8.json`` — the machine-readable perf trajectory (render
speedups, max-error, lane + chunk occupancy, batched-serving throughput/
occupancy/latency, continuous-vs-microbatch scheduler sweep, culled-octree
throughput + visible-fraction stats, fused-vs-unfused raster throughput and
error decomposition, quantized-resident bytes/req-s/PSNR, and the
``repro.obs`` metrics-registry snapshot: in-kernel early-exit depth,
lane/chunk occupancy, cull visibility, resident bytes) — to the repo
root, then collates every checked-in ``BENCH_PR*.json`` into the
``BENCH_TRAJECTORY.md`` perf-trajectory table (``benchmarks.report``).
"""

from __future__ import annotations

import json
import pathlib
import sys
import traceback

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = REPO_ROOT / "BENCH_PR8.json"


def main() -> None:
    from benchmarks import (
        bench_compress,
        bench_culling,
        bench_fig5_parallelism,
        bench_fused,
        bench_lm_steps,
        bench_obs,
        bench_serving,
        bench_table1_kernels,
        bench_table2_throughput,
        report,
    )

    print("name,us_per_call,derived")
    metrics: dict = {}
    for mod in (
        bench_table1_kernels,
        bench_table2_throughput,
        bench_fig5_parallelism,
        bench_lm_steps,
        bench_serving,
        bench_culling,
        bench_fused,
        bench_compress,
        bench_obs,
    ):
        try:
            section = mod.main()
        except Exception:
            print(f"# {mod.__name__} FAILED", file=sys.stderr)
            traceback.print_exc()
            raise
        if isinstance(section, dict):
            metrics[mod.__name__.removeprefix("benchmarks.")] = section

    BENCH_JSON.write_text(json.dumps(metrics, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {BENCH_JSON}", file=sys.stderr)

    trajectory = REPO_ROOT / "BENCH_TRAJECTORY.md"
    trajectory.write_text(report.trajectory_table(REPO_ROOT))
    print(f"# wrote {trajectory}", file=sys.stderr)


if __name__ == "__main__":
    main()
