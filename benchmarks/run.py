"""Benchmark aggregator. One section per paper table/figure + substrate.

Prints ``name,us_per_call,derived`` CSV lines (the repo-wide contract) and
writes ``BENCH_PR10.json`` — the machine-readable perf trajectory (render
speedups, max-error, lane + chunk occupancy, batched-serving throughput/
occupancy/latency, continuous-vs-microbatch scheduler sweep, culled-octree
throughput + visible-fraction stats, fused-vs-unfused raster throughput and
error decomposition, quantized-resident bytes/req-s/PSNR, and the
``repro.obs`` metrics-registry snapshot: in-kernel early-exit depth,
lane/chunk occupancy, cull visibility, resident bytes) — to the repo
root, then collates every checked-in ``BENCH_PR*.json`` into the
``BENCH_TRAJECTORY.md`` perf-trajectory table (``benchmarks.report``).

Every results file carries a top-level ``_meta`` provenance table —
``{schema_version, git_sha, date, hostname, trials, profile}`` — so
``tools/perfguard`` (and anyone reading the trajectory) knows where each
number came from and whether two files are comparable. Modes:

* ``--tiny`` runs the smoke-scale variant of every section that has one
  (profile ``"tiny"``; sections without a tiny mode are skipped) —
  this is what CI's perfguard job measures.
* ``--trials N`` repeats the whole sweep N times and stores every numeric
  leaf as a list of per-trial samples (newest schema; ``N=1`` keeps the
  scalar form). perfguard reduces either form to its median.
* ``--out PATH`` redirects the results file (CI writes to a temp path so
  a smoke run never dirties the committed trajectory).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = REPO_ROOT / "BENCH_PR10.json"


def _merge_trials(acc, new):
    """Fold one trial's section tree into the accumulator, leaf-wise.

    Numeric leaves accumulate into per-trial sample lists; everything else
    (strings, registry snapshots, pre-existing lists) keeps trial 0's
    value — re-measuring a config echo or a metrics snapshot adds nothing.
    """
    if isinstance(acc, dict) and isinstance(new, dict):
        out = dict(acc)
        for k, v in new.items():
            out[k] = _merge_trials(acc[k], v) if k in acc else v
        return out
    if isinstance(acc, list) and all(
        isinstance(x, (int, float)) and not isinstance(x, bool) for x in acc
    ):
        if isinstance(new, (int, float)) and not isinstance(new, bool):
            return acc + [new]
        return acc
    if (
        isinstance(acc, (int, float))
        and not isinstance(acc, bool)
        and isinstance(new, (int, float))
        and not isinstance(new, bool)
    ):
        return [acc, new]
    return acc


def _run_once(tiny: bool) -> dict:
    from benchmarks import (
        bench_compress,
        bench_culling,
        bench_fig5_parallelism,
        bench_fused,
        bench_lm_steps,
        bench_obs,
        bench_serving,
        bench_table1_kernels,
        bench_table2_throughput,
    )

    # (module, supports --tiny). Sections without a tiny mode only run at
    # full scale — the tiny profile is the CI smoke subset, not a slower
    # spelling of the full sweep.
    mods = [
        (bench_table1_kernels, False),
        (bench_table2_throughput, True),
        (bench_fig5_parallelism, False),
        (bench_lm_steps, False),
        (bench_serving, True),
        (bench_culling, True),
        (bench_fused, True),
        (bench_compress, True),
        (bench_obs, True),
    ]
    metrics: dict = {}
    for mod, has_tiny in mods:
        if tiny and not has_tiny:
            print(f"# {mod.__name__}: no tiny mode, skipped", file=sys.stderr)
            continue
        try:
            section = mod.main(("--tiny",)) if (tiny and has_tiny) else mod.main()
        except Exception:
            print(f"# {mod.__name__} FAILED", file=sys.stderr)
            traceback.print_exc()
            raise
        if isinstance(section, dict):
            metrics[mod.__name__.removeprefix("benchmarks.")] = section
    return metrics


def main(argv: tuple[str, ...] | list[str] | None = None) -> None:
    from tools.perfguard.bench import provenance_meta

    from benchmarks import report

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--tiny", action="store_true",
        help="smoke scale: run each section's --tiny variant (profile=tiny)",
    )
    ap.add_argument(
        "--trials", type=int, default=1,
        help="repeat the sweep N times; numeric leaves become sample lists",
    )
    ap.add_argument(
        "--out", default=None,
        help=f"results path (default: {BENCH_JSON.name} in the repo root)",
    )
    args = ap.parse_args(argv)
    if args.trials < 1:
        ap.error("--trials must be >= 1")
    out_path = pathlib.Path(args.out) if args.out else BENCH_JSON

    print("name,us_per_call,derived")
    metrics = _run_once(args.tiny)
    for trial in range(1, args.trials):
        print(f"# trial {trial + 1}/{args.trials}", file=sys.stderr)
        metrics = _merge_trials(metrics, _run_once(args.tiny))

    metrics["_meta"] = provenance_meta(
        trials=args.trials,
        profile="tiny" if args.tiny else "full",
        root=REPO_ROOT,
    )
    out_path.write_text(json.dumps(metrics, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {out_path}", file=sys.stderr)

    # The trajectory table collates only the *committed* repo-root files,
    # so regenerating it after a smoke run writes the same bytes.
    trajectory = REPO_ROOT / "BENCH_TRAJECTORY.md"
    trajectory.write_text(report.trajectory_table(REPO_ROOT))
    print(f"# wrote {trajectory}", file=sys.stderr)


if __name__ == "__main__":
    main()
