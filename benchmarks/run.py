"""Benchmark aggregator. One section per paper table/figure + substrate.

Prints ``name,us_per_call,derived`` CSV lines (the repo-wide contract).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_fig5_parallelism,
        bench_lm_steps,
        bench_table1_kernels,
        bench_table2_throughput,
    )

    print("name,us_per_call,derived")
    for mod in (
        bench_table1_kernels,
        bench_table2_throughput,
        bench_fig5_parallelism,
        bench_lm_steps,
    ):
        try:
            mod.main()
        except Exception:
            print(f"# {mod.__name__} FAILED", file=sys.stderr)
            traceback.print_exc()
            raise


if __name__ == "__main__":
    main()
