"""Paper Table II analogue: end-to-end feature-computation throughput (MB/s).

The paper measures MB/s of Gaussian records (59 f32 = 236 B each) through the
feature pipeline for Non-AIE (PS only) / Naive / Stream / Window methods,
finding ~45 MB/s on hardware (PL DataMover-bound) vs near-linear scaling in
the AIE simulator. Our ladder on this container (CPU wall-clock):

  naive        — per-Gaussian scalar loops, stage-at-a-time (paper Naive)
  staged       — SoA-vectorized, stage-at-a-time w/ HBM round trips
                 (paper Stream/Window in-tile optimized)
  fused        — whole pipeline in one jit (beyond-paper fusion)
  fused_pallas — the Pallas kernel in interpret mode (correctness path on
                 CPU; compiled Mosaic on real TPU — see the roofline model
                 for the TPU-target number)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import features as F
from repro.core import look_at_camera, random_gaussians
from repro.core.gaussians import GAUSSIAN_RECORD_BYTES
from repro.kernels.gaussian_features.ops import gaussian_features_packed

N = 200_000


def staged_separate_jits(cam):
    """Stage-at-a-time execution: each stage its own jit (HBM round trips)."""
    j_cov3d = jax.jit(lambda q, s: F.stage_cov3d(q, s))
    j_proj = jax.jit(lambda p: F.stage_projection(p, cam))
    j_jac = jax.jit(lambda pc: F.stage_jacobian(pc, cam))
    j_cov2d = jax.jit(lambda c3, jc: F.stage_cov2d(c3, jc, cam))
    j_inv = jax.jit(F.stage_cov2d_inv)
    j_dir = jax.jit(lambda p: F.stage_ray_dir(p, cam))
    j_color = jax.jit(lambda sh, r: F.stage_color(sh, r))

    def run(g):
        cov3d = j_cov3d(g.quats, g.scales())
        p_cam, uv, depth = j_proj(g.positions)
        jac = j_jac(p_cam)
        cov2d = j_cov2d(cov3d, jac)
        conic, radius = j_inv(cov2d)
        rdir = j_dir(g.positions)
        color = j_color(g.sh, rdir)
        return uv, conic, radius, color, depth

    return run


def naive_separate_jits(cam):
    """Paper Naive: per-Gaussian scalar loops AND stage-at-a-time round trips."""
    j_cov3d = jax.jit(jax.vmap(F._naive_cov3d_single))
    j_proj = jax.jit(lambda p: F.stage_projection(p, cam))
    j_jac = jax.jit(lambda pc: F.stage_jacobian(pc, cam))
    j_cov2d = jax.jit(
        jax.vmap(F._naive_cov2d_single, in_axes=(0, 0, None)), static_argnums=()
    )
    j_inv = jax.jit(F.stage_cov2d_inv)
    j_dir = jax.jit(lambda p: F.stage_ray_dir(p, cam))
    j_color = jax.jit(lambda sh, r: F.stage_color(sh, r))

    def run(g):
        cov3d = j_cov3d(g.quats, g.scales())
        p_cam, uv, depth = j_proj(g.positions)
        jac = j_jac(p_cam)
        cov2d = j_cov2d(cov3d, jac, cam.r_cw)
        conic, radius = j_inv(cov2d)
        rdir = j_dir(g.positions)
        color = j_color(g.sh, rdir)
        return uv, conic, radius, color, depth

    return run


def main() -> None:
    g = random_gaussians(jax.random.PRNGKey(0), N)
    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=1024, height=1024)
    mb = N * GAUSSIAN_RECORD_BYTES / 1e6

    run_naive = naive_separate_jits(cam)
    t_naive = time_fn(run_naive, g, warmup=1, iters=3)
    emit("table2/naive", t_naive, f"{mb / (t_naive / 1e6):.1f}MBps")

    run_staged = staged_separate_jits(cam)
    t_staged = time_fn(run_staged, g, warmup=1, iters=3)
    emit("table2/staged", t_staged, f"{mb / (t_staged / 1e6):.1f}MBps")

    t_fused = time_fn(
        jax.jit(lambda g: F.compute_features_fused(g, cam)), g, warmup=1, iters=3
    )
    emit("table2/fused", t_fused, f"{mb / (t_fused / 1e6):.1f}MBps")

    t_pallas = time_fn(
        lambda g: gaussian_features_packed(g, cam), g, warmup=1, iters=3
    )
    emit(
        "table2/fused_pallas_interpret",
        t_pallas,
        f"{mb / (t_pallas / 1e6):.1f}MBps",
    )


if __name__ == "__main__":
    main()
