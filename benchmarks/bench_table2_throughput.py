"""Paper Table II analogue: end-to-end feature-computation throughput (MB/s).

The paper measures MB/s of Gaussian records (59 f32 = 236 B each) through the
feature pipeline for Non-AIE (PS only) / Naive / Stream / Window methods,
finding ~45 MB/s on hardware (PL DataMover-bound) vs near-linear scaling in
the AIE simulator. Our ladder on this container (CPU wall-clock):

  naive        — per-Gaussian scalar loops, stage-at-a-time (paper Naive)
  staged       — SoA-vectorized, stage-at-a-time w/ HBM round trips
                 (paper Stream/Window in-tile optimized)
  fused        — whole pipeline in one jit (beyond-paper fusion)
  fused_pallas — the Pallas kernel in interpret mode (correctness path on
                 CPU; compiled Mosaic on real TPU — see the roofline model
                 for the TPU-target number)
"""

# reprolint: disable-file=retrace-hazard -- this benchmark's subject IS the
# jit-assembly strategy: staged/naive deliberately build one jit per pipeline
# stage (the HBM-round-trip baselines the fused path is measured against).

from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit, time_fn
from repro.core import RenderConfig, features as F
from repro.core import clustered_gaussians, look_at_camera, random_gaussians
from repro.core.gaussians import GAUSSIAN_RECORD_BYTES
from repro.core.render import render_jit
from repro.kernels.gaussian_features.ops import gaussian_features_packed

N = 200_000

# End-to-end render benchmark (dense oracle vs tile-binned raster).
RENDER_N = 8_192
RENDER_SIZE = 256

# --tiny smoke dimensions (CI: seconds, not minutes).
TINY_N = 2_048
TINY_SIZE = 128


def staged_separate_jits(cam):
    """Stage-at-a-time execution: each stage its own jit (HBM round trips)."""
    j_cov3d = jax.jit(lambda q, s: F.stage_cov3d(q, s))
    j_proj = jax.jit(lambda p: F.stage_projection(p, cam))
    j_jac = jax.jit(lambda pc: F.stage_jacobian(pc, cam))
    j_cov2d = jax.jit(lambda c3, jc: F.stage_cov2d(c3, jc, cam))
    j_inv = jax.jit(F.stage_cov2d_inv)
    j_dir = jax.jit(lambda p: F.stage_ray_dir(p, cam))
    j_color = jax.jit(lambda sh, r: F.stage_color(sh, r))

    def run(g):
        cov3d = j_cov3d(g.quats, g.scales())
        p_cam, uv, depth = j_proj(g.positions)
        jac = j_jac(p_cam)
        cov2d = j_cov2d(cov3d, jac)
        conic, radius = j_inv(cov2d)
        rdir = j_dir(g.positions)
        color = j_color(g.sh, rdir)
        return uv, conic, radius, color, depth

    return run


def naive_separate_jits(cam):
    """Paper Naive: per-Gaussian scalar loops AND stage-at-a-time round trips."""
    j_cov3d = jax.jit(jax.vmap(F._naive_cov3d_single))
    j_proj = jax.jit(lambda p: F.stage_projection(p, cam))
    j_jac = jax.jit(lambda pc: F.stage_jacobian(pc, cam))
    j_cov2d = jax.jit(
        jax.vmap(F._naive_cov2d_single, in_axes=(0, 0, None)), static_argnums=()
    )
    j_inv = jax.jit(F.stage_cov2d_inv)
    j_dir = jax.jit(lambda p: F.stage_ray_dir(p, cam))
    j_color = jax.jit(lambda sh, r: F.stage_color(sh, r))

    def run(g):
        cov3d = j_cov3d(g.quats, g.scales())
        p_cam, uv, depth = j_proj(g.positions)
        jac = j_jac(p_cam)
        cov2d = j_cov2d(cov3d, jac, cam.r_cw)
        conic, radius = j_inv(cov2d)
        rdir = j_dir(g.positions)
        color = j_color(g.sh, rdir)
        return uv, conic, radius, color, depth

    return run


def main(argv: tuple[str, ...] | list[str] = ()) -> dict:
    """Run the Table II benchmarks. Returns machine-readable metrics
    (``benchmarks/run.py`` folds them into ``BENCH_PR2.json``).

    ``argv`` defaults to empty so programmatic callers (the aggregator)
    never inherit the invoking process's command line.
    """
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny",
        action="store_true",
        help="CI smoke: small scene, render section only, asserts "
        "binned >= dense and compact >= block-list throughput",
    )
    args = ap.parse_args(list(argv))

    if args.tiny:
        return {"render": render_throughput(tiny=True)}

    g = random_gaussians(jax.random.PRNGKey(0), N)
    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=1024, height=1024)
    mb = N * GAUSSIAN_RECORD_BYTES / 1e6
    feature_us = {}

    run_naive = naive_separate_jits(cam)
    t_naive = time_fn(run_naive, g, warmup=1, iters=3)
    feature_us["naive"] = t_naive
    emit("table2/naive", t_naive, f"{mb / (t_naive / 1e6):.1f}MBps")

    run_staged = staged_separate_jits(cam)
    t_staged = time_fn(run_staged, g, warmup=1, iters=3)
    feature_us["staged"] = t_staged
    emit("table2/staged", t_staged, f"{mb / (t_staged / 1e6):.1f}MBps")

    t_fused = time_fn(
        jax.jit(lambda g: F.compute_features_fused(g, cam)), g, warmup=1, iters=3
    )
    feature_us["fused"] = t_fused
    emit("table2/fused", t_fused, f"{mb / (t_fused / 1e6):.1f}MBps")

    t_pallas = time_fn(
        lambda g: gaussian_features_packed(g, cam), g, warmup=1, iters=3
    )
    feature_us["fused_pallas_interpret"] = t_pallas
    emit(
        "table2/fused_pallas_interpret",
        t_pallas,
        f"{mb / (t_pallas / 1e6):.1f}MBps",
    )

    return {"feature_us": feature_us, "render": render_throughput()}


def render_throughput(tiny: bool = False) -> dict:
    """End-to-end render wall clock across every raster path, two scenes.

    Uniform scene: the binned paths' win over dense is the tile-binning
    subsystem's whole point. Clustered scene: the *non-uniform* case where
    per-tile Gaussian compaction beats block-granular sparsity hardest —
    depth-consecutive 128-wide blocks scatter across the screen, so the
    block-list kernel blends ~97% masked lanes while the compacted kernel's
    lanes are live Gaussians. Every speedup is emitted alongside its
    max-error vs the dense oracle and the tile-overflow rate — a speedup
    number without its error bar is not a result.
    """
    import jax.numpy as jnp

    from repro.core.binning import lane_occupancy_stats
    from repro.core.features import compute_features_fused
    from repro.core.rasterize import sort_by_depth
    from repro.obs.metrics import Registry
    from repro.obs.pipeline import fold_memory, fold_occupancy

    # Occupancy/memory also land in a metrics registry (repro.obs): the
    # snapshot below uses the same canonical series names the render
    # server exports, so BENCH_PR*.json and a live /metrics endpoint are
    # directly comparable.
    registry = Registry()

    n = TINY_N if tiny else RENDER_N
    size = TINY_SIZE if tiny else RENDER_SIZE
    # Always 3 timing samples: time_fn takes the median, and with an even
    # count it would return the worse sample — on a noisy shared CI runner
    # the --tiny asserts below need a true median (they have 4-7x headroom).
    iters = 3
    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=size, height=size)
    mpix = size * size / 1e6
    base_cfg = RenderConfig()

    scenes = [
        ("uniform", random_gaussians(jax.random.PRNGKey(1), n, extent=1.5)),
        ("clustered", clustered_gaussians(jax.random.PRNGKey(2), n)),
    ]
    metrics: dict = {"gaussians": n, "image_size": size, "scenes": {}}

    for scene, g in scenes:
        results: dict = {}
        imgs = {}
        for path in ("dense", "binned", "pallas", "pallas_binned"):
            cfg = base_cfg.replace(raster_path=path)
            t = time_fn(
                lambda gg, c=cfg: render_jit(gg, cam, c), g, warmup=1,
                iters=iters,
            )
            results[path] = t
            imgs[path] = render_jit(g, cam, cfg)
            emit(
                f"table2/{scene}_render_{path}_{n}g_{size}px",
                t,
                f"{mpix / (t / 1e6):.2f}Mpix_s",
            )

        speedups = {
            path: results["dense"] / results[path]
            for path in ("binned", "pallas", "pallas_binned")
        }
        max_err = {
            path: float(jnp.max(jnp.abs(imgs["dense"] - imgs[path])))
            for path in ("binned", "pallas", "pallas_binned")
        }
        # Compacted-vs-block-list: the head-to-head the compaction stage is
        # for. Same tiles, same Gaussians, same Pallas substrate — only the
        # work-list format differs.
        compact_vs_block = results["pallas"] / results["pallas_binned"]

        feats = sort_by_depth(compute_features_fused(g, cam))
        occ = lane_occupancy_stats(
            feats, size, size,
            tile_size=base_cfg.tile_size,
            capacity=base_cfg.tile_capacity,
            block_g=base_cfg.block_g,
        )
        fold_occupancy(registry, occ, scene=scene)

        for path, s in speedups.items():
            emit(f"table2/{scene}_render_{path}_speedup", s, f"{s:.2f}x")
        emit(
            f"table2/{scene}_compact_vs_block_speedup",
            compact_vs_block,
            f"{compact_vs_block:.2f}x",
        )
        emit(
            f"table2/{scene}_lane_occupancy",
            occ["compact_occupancy"],
            f"compact={occ['compact_occupancy']:.1%}_"
            f"block={occ['block_occupancy']:.1%}",
        )
        # Chunk-level occupancy: the streaming/early-exit granularity of
        # the compacted kernels (full chunks save a whole fetch+blend step
        # when skipped; only tile tails run partially live).
        emit(
            f"table2/{scene}_chunk_occupancy",
            occ["chunk_full_fraction"],
            f"full={occ['chunk_full_fraction']:.1%}_"
            f"tail={occ['chunk_tail_occupancy']:.1%}_"
            f"per_tile_mean={occ['chunks_per_tile_mean']:.1f}",
        )
        emit(
            f"table2/{scene}_render_binned_max_err",
            max_err["binned"],
            f"overflow_tiles={occ['overflow_rate']:.1%}",
        )

        metrics["scenes"][scene] = {
            "us_per_frame": results,
            "speedup_vs_dense": speedups,
            "compact_vs_block_speedup": compact_vs_block,
            "max_err_vs_dense": max_err,
            "lane_occupancy": occ,
        }

    # Resident-bytes accounting (quantized resident scenes, core.quant):
    # the clustered cloud as a SceneTree at f32 vs int8 storage.
    from repro.core import build_scene_tree

    g_clu = dict(scenes)["clustered"]
    memory = {
        mode: build_scene_tree(g_clu, leaf_size=256, compress=mode).memory_stats()
        for mode in ("none", "int8")
    }
    byte_ratio = memory["int8"]["total_bytes"] / memory["none"]["total_bytes"]
    emit(
        "table2/resident_bytes_int8_vs_f32",
        byte_ratio,
        f"{memory['int8']['total_bytes'] / 1e6:.1f}MB_{byte_ratio:.3f}x",
    )
    metrics["memory"] = memory
    for mode, mem in memory.items():
        fold_memory(registry, mem, compress=mode)
    metrics["registry"] = registry.snapshot()

    if tiny:
        uni = metrics["scenes"]["uniform"]
        assert uni["speedup_vs_dense"]["binned"] >= 1.0, (
            f"binned slower than dense: {uni['speedup_vs_dense']}"
        )
        clu = metrics["scenes"]["clustered"]
        assert clu["compact_vs_block_speedup"] >= 1.0, (
            f"compact kernel slower than block-list: {clu}"
        )
        assert (
            clu["lane_occupancy"]["compact_occupancy"]
            > clu["lane_occupancy"]["block_occupancy"]
        ), clu["lane_occupancy"]
        print("# tiny smoke OK: binned >= dense, compact >= block-list")

    return metrics


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
