"""Paper Table II analogue: end-to-end feature-computation throughput (MB/s).

The paper measures MB/s of Gaussian records (59 f32 = 236 B each) through the
feature pipeline for Non-AIE (PS only) / Naive / Stream / Window methods,
finding ~45 MB/s on hardware (PL DataMover-bound) vs near-linear scaling in
the AIE simulator. Our ladder on this container (CPU wall-clock):

  naive        — per-Gaussian scalar loops, stage-at-a-time (paper Naive)
  staged       — SoA-vectorized, stage-at-a-time w/ HBM round trips
                 (paper Stream/Window in-tile optimized)
  fused        — whole pipeline in one jit (beyond-paper fusion)
  fused_pallas — the Pallas kernel in interpret mode (correctness path on
                 CPU; compiled Mosaic on real TPU — see the roofline model
                 for the TPU-target number)
"""

from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core import RenderConfig, features as F
from repro.core import look_at_camera, random_gaussians
from repro.core.gaussians import GAUSSIAN_RECORD_BYTES
from repro.core.render import render_jit
from repro.kernels.gaussian_features.ops import gaussian_features_packed

N = 200_000

# End-to-end render benchmark (dense oracle vs tile-binned raster).
RENDER_N = 8_192
RENDER_SIZE = 256


def staged_separate_jits(cam):
    """Stage-at-a-time execution: each stage its own jit (HBM round trips)."""
    j_cov3d = jax.jit(lambda q, s: F.stage_cov3d(q, s))
    j_proj = jax.jit(lambda p: F.stage_projection(p, cam))
    j_jac = jax.jit(lambda pc: F.stage_jacobian(pc, cam))
    j_cov2d = jax.jit(lambda c3, jc: F.stage_cov2d(c3, jc, cam))
    j_inv = jax.jit(F.stage_cov2d_inv)
    j_dir = jax.jit(lambda p: F.stage_ray_dir(p, cam))
    j_color = jax.jit(lambda sh, r: F.stage_color(sh, r))

    def run(g):
        cov3d = j_cov3d(g.quats, g.scales())
        p_cam, uv, depth = j_proj(g.positions)
        jac = j_jac(p_cam)
        cov2d = j_cov2d(cov3d, jac)
        conic, radius = j_inv(cov2d)
        rdir = j_dir(g.positions)
        color = j_color(g.sh, rdir)
        return uv, conic, radius, color, depth

    return run


def naive_separate_jits(cam):
    """Paper Naive: per-Gaussian scalar loops AND stage-at-a-time round trips."""
    j_cov3d = jax.jit(jax.vmap(F._naive_cov3d_single))
    j_proj = jax.jit(lambda p: F.stage_projection(p, cam))
    j_jac = jax.jit(lambda pc: F.stage_jacobian(pc, cam))
    j_cov2d = jax.jit(
        jax.vmap(F._naive_cov2d_single, in_axes=(0, 0, None)), static_argnums=()
    )
    j_inv = jax.jit(F.stage_cov2d_inv)
    j_dir = jax.jit(lambda p: F.stage_ray_dir(p, cam))
    j_color = jax.jit(lambda sh, r: F.stage_color(sh, r))

    def run(g):
        cov3d = j_cov3d(g.quats, g.scales())
        p_cam, uv, depth = j_proj(g.positions)
        jac = j_jac(p_cam)
        cov2d = j_cov2d(cov3d, jac, cam.r_cw)
        conic, radius = j_inv(cov2d)
        rdir = j_dir(g.positions)
        color = j_color(g.sh, rdir)
        return uv, conic, radius, color, depth

    return run


def main() -> None:
    g = random_gaussians(jax.random.PRNGKey(0), N)
    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=1024, height=1024)
    mb = N * GAUSSIAN_RECORD_BYTES / 1e6

    run_naive = naive_separate_jits(cam)
    t_naive = time_fn(run_naive, g, warmup=1, iters=3)
    emit("table2/naive", t_naive, f"{mb / (t_naive / 1e6):.1f}MBps")

    run_staged = staged_separate_jits(cam)
    t_staged = time_fn(run_staged, g, warmup=1, iters=3)
    emit("table2/staged", t_staged, f"{mb / (t_staged / 1e6):.1f}MBps")

    t_fused = time_fn(
        jax.jit(lambda g: F.compute_features_fused(g, cam)), g, warmup=1, iters=3
    )
    emit("table2/fused", t_fused, f"{mb / (t_fused / 1e6):.1f}MBps")

    t_pallas = time_fn(
        lambda g: gaussian_features_packed(g, cam), g, warmup=1, iters=3
    )
    emit(
        "table2/fused_pallas_interpret",
        t_pallas,
        f"{mb / (t_pallas / 1e6):.1f}MBps",
    )

    render_throughput()


def render_throughput() -> None:
    """End-to-end render wall clock: dense O(P*G) vs tile-binned raster.

    The binned path's win is the whole point of the tile-binning subsystem:
    each 16x16 tile blends only the Gaussians whose 3-sigma AABB overlaps it,
    instead of all of them. Binned runs at the production tile_capacity, so
    the fidelity vs the exact dense oracle (list overflow drops back-most
    Gaussians) is emitted alongside the speedup — a speedup number without
    its error bar is not a result.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core.binning import bin_gaussians
    from repro.core.features import compute_features_fused
    from repro.core.rasterize import sort_by_depth

    g = random_gaussians(jax.random.PRNGKey(1), RENDER_N, extent=1.5)
    cam = look_at_camera(
        (0, 1.0, -6.0), (0, 0, 0), width=RENDER_SIZE, height=RENDER_SIZE
    )
    mpix = RENDER_SIZE * RENDER_SIZE / 1e6

    results = {}
    imgs = {}
    for path in ("dense", "binned"):
        cfg = RenderConfig(raster_path=path)
        t = time_fn(
            lambda gg, c=cfg: render_jit(gg, cam, c), g, warmup=1, iters=3
        )
        results[path] = t
        imgs[path] = render_jit(g, cam, cfg)
        emit(
            f"table2/render_{path}_{RENDER_N}g_{RENDER_SIZE}px",
            t,
            f"{mpix / (t / 1e6):.2f}Mpix_s",
        )
    speedup = results["dense"] / results["binned"]
    emit("table2/render_binned_speedup", speedup, f"{speedup:.2f}x")

    err = float(jnp.max(jnp.abs(imgs["dense"] - imgs["binned"])))
    feats = sort_by_depth(compute_features_fused(g, cam))
    bins = bin_gaussians(
        feats,
        RENDER_SIZE,
        RENDER_SIZE,
        capacity=RenderConfig().tile_capacity,
    )
    over = float(np.asarray(bins.overflowed).mean())
    emit("table2/render_binned_max_err", err, f"overflow_tiles={over:.1%}")


if __name__ == "__main__":
    main()
