"""Observability: pipeline-health registry snapshot + serving telemetry smoke.

Everything the ``repro.obs`` subsystem measures, exercised end to end and
persisted machine-readably:

* ``--tiny`` (the CI smoke) replays the ``bench_serving --tiny`` load shape
  through a continuous-batching :class:`repro.serve.RenderServer` with a
  metrics registry and a tracer attached, then validates the whole export
  surface: the Prometheus text exposition is fetched over HTTP from a live
  ``serve_metrics`` endpoint and schema-checked (``validate_prometheus``),
  the Chrome trace JSON is written to ``--trace-out`` and schema-checked
  (``validate_trace``, the same file Perfetto loads), the ``stats()``
  schema is pinned, and the stats memory is asserted bounded (ring
  buffers, no unbounded per-request lists). One small ``pallas_fused``
  render with ``collect_stats`` folds in-kernel counters into the same
  registry so the snapshot covers every metric family.
* full mode (default; ``benchmarks/run.py``) renders the headline 500k
  clustered culled + fused + int8-resident config under
  ``render_with_stats`` and folds the in-kernel diagnostics plane (chunks
  processed before early exit, lanes blended, max SH band decoded), cull
  visibility fraction, compacted lane/chunk occupancy (the
  ``pallas_binned`` view of the same scene) and quantized resident bytes
  into one registry whose ``snapshot()`` lands in ``BENCH_PR8.json`` —
  rendered as a pipeline-health table by ``report.py --section obs``.

    PYTHONPATH=src python -m benchmarks.bench_obs [--tiny]
        [--trace-out /tmp/serve_trace.json]
"""

from __future__ import annotations

import argparse
import json
import tempfile
import urllib.request

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import (
    RenderConfig,
    build_scene_tree,
    orbit_cameras,
    random_gaussians,
)
from repro.core.render import render_with_stats
from repro.obs.metrics import Registry, serve_metrics, validate_prometheus
from repro.obs.pipeline import fold_memory, fold_render_stats
from repro.obs.tracing import Tracer, validate_trace
from repro.serve import RenderServer, replay_schedule

# Full-mode headline config (matches bench_fused's 500k clustered entry).
N = 500_000
SIZE = 256
LEAF_SIZE = 256
LOD_THRESHOLDS = (0.2, 0.5)

# Tiny mode replicates the bench_serving --tiny load shape.
TINY_N = 4_096
TINY_SIZE = 96
TINY_REQUESTS = 24
TINY_BATCH = 8

STATS_KEYS = {
    "mode", "requests", "batches", "compile_ms", "latency_ms_p50",
    "latency_ms_p95", "latency_ms_mean", "mean_batch_size", "occupancy",
    "memory", "slo",
}


def _serve_load(registry: Registry, tracer: Tracer) -> dict:
    """Replay a burst of requests through a continuous server that reports
    into ``registry``/``tracer``; returns its ``stats()``."""
    g = random_gaussians(jax.random.PRNGKey(0), TINY_N, extent=1.5)
    cfg = RenderConfig(raster_path="binned")
    cams = orbit_cameras(
        TINY_REQUESTS, radius=5.0, width=TINY_SIZE, height=TINY_SIZE
    )
    server = RenderServer(
        g, cfg, width=TINY_SIZE, height=TINY_SIZE, max_batch=TINY_BATCH,
        registry=registry, tracer=tracer,
    )
    with server:
        results, wall = replay_schedule(
            server.submit, cams, np.zeros(len(cams))
        )
    stats = server.stats()
    assert set(stats) == STATS_KEYS, sorted(stats)
    # Bounded memory: percentiles come from a fixed ring, and the old
    # unbounded per-request lists are gone.
    assert len(server._lat._ring) == server.registry.histogram(
        "render_server_latency_ms"
    ).ring_size
    assert not hasattr(server, "_latencies_ms")
    assert not hasattr(server, "_batch_sizes")
    emit(
        "obs/serve_tiny_req_s",
        1e6 * wall / len(results),
        f"{len(results) / wall:.2f}req_s",
    )
    return stats


def _fold_kernel_smoke(registry: Registry) -> None:
    """One small fused render with collect_stats, folded into ``registry``
    so the tiny snapshot covers the in-kernel counter families too."""
    g = random_gaussians(jax.random.PRNGKey(1), 2_048, extent=1.5)
    cam = orbit_cameras(1, radius=5.0, width=64, height=64)[0]
    cfg = RenderConfig(
        raster_path="pallas_fused", tile_capacity=128, collect_stats=True
    )
    _, st = render_with_stats(g, cam, cfg)
    fold_render_stats(registry, st, surface="smoke")


def tiny(trace_out: str | None) -> dict:
    registry, tracer = Registry(), Tracer()
    stats = _serve_load(registry, tracer)
    _fold_kernel_smoke(registry)

    # Export surface 1: Prometheus text, fetched from a live endpoint.
    http = serve_metrics(registry, port=0)
    try:
        port = http.server_address[1]
        with urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics"
        ) as resp:
            text = resp.read().decode()
    finally:
        http.shutdown()
    families = validate_prometheus(text)
    for fam in (
        "render_server_latency_ms",
        "render_server_batch_size",
        "render_server_requests_total",
        "render_chunks_processed",
    ):
        assert fam in families, (fam, sorted(families))

    # Export surface 2: the Chrome trace JSON Perfetto loads.
    if trace_out is None:
        trace_out = tempfile.mktemp(suffix=".json", prefix="serve_trace_")
    tracer.save(trace_out)
    with open(trace_out) as f:
        trace = json.load(f)
    n_events = validate_trace(trace)
    names = {e["name"] for e in trace["traceEvents"] if e.get("ph") == "X"}
    assert {"queue", "render", "harvest", "warmup_compile"} <= names, names

    print(
        f"# tiny smoke OK: {len(families)} metric families validated, "
        f"{n_events} trace events validated ({trace_out}), "
        f"server p95 {stats['latency_ms_p95']:.1f} ms"
    )
    return {
        "mode": "tiny",
        "server_stats": {k: v for k, v in stats.items() if k != "memory"},
        "prometheus_families": sorted(families),
        "trace_events": n_events,
        "registry": registry.snapshot(),
    }


def full() -> dict:
    from benchmarks.bench_fused import inside_cameras, make_scene

    g = make_scene("clustered", N)
    tree = build_scene_tree(g, leaf_size=LEAF_SIZE, compress="int8")
    cam = inside_cameras(1, SIZE)[0]
    registry = Registry()

    base = RenderConfig(
        cull=True, compress="int8", lod_thresholds=LOD_THRESHOLDS,
        collect_stats=True,
    )
    # In-kernel diagnostics plane + cull visibility on the headline
    # culled + fused + int8 decode-in-kernel config.
    _, st_fused = render_with_stats(
        tree, cam, base.replace(raster_path="pallas_fused")
    )
    agg = fold_render_stats(registry, st_fused, config="culled_fused_int8")
    # Lane/chunk occupancy is a property of the compacted tile lists; the
    # pallas_binned view of the same scene measures it host-side.
    _, st_binned = render_with_stats(
        tree, cam, base.replace(raster_path="pallas_binned")
    )
    fold_render_stats(registry, st_binned, config="culled_binned_int8")
    fold_memory(registry, tree.memory_stats(), config="culled_fused_int8")

    vis = st_fused["visibility"]
    emit(
        "obs/cull_visible_fraction",
        vis["visible_fraction"],
        f"{vis['visible_fraction']:.1%}",
    )
    emit(
        "obs/early_exit_savings",
        agg["early_exit_savings"],
        f"{agg['early_exit_savings']:.1%}_of_assigned_chunks",
    )
    emit(
        "obs/chunk_occupancy_measured",
        agg["chunk_occupancy_measured"],
        f"{agg['chunk_occupancy_measured']:.1%}_lanes_live",
    )
    mem = tree.memory_stats()
    emit(
        "obs/resident_bytes",
        mem["total_bytes"],
        f"{mem['total_bytes'] / 1e6:.1f}MB_{mem['ratio_vs_f32']:.3f}x_f32",
    )
    return {
        "mode": "full",
        "gaussians": N,
        "image_size": SIZE,
        "kernel": agg,
        "visibility": vis,
        "registry": registry.snapshot(),
    }


def main(argv: tuple[str, ...] | list[str] = ()) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny",
        action="store_true",
        help="CI smoke: short continuous-batching serve, validates the "
        "Prometheus exposition + Chrome trace schema end to end",
    )
    ap.add_argument(
        "--trace-out",
        default=None,
        help="where --tiny writes the Chrome trace JSON (default: a temp "
        "file)",
    )
    args = ap.parse_args(list(argv))
    return tiny(args.trace_out) if args.tiny else full()


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
