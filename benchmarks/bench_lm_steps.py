"""LM substrate step benchmarks: smoke-config train/decode wall time per arch.

Not a paper table — tracks the substrate's CPU-measurable health and feeds
the 'useful-flops' sanity check (analytic flops / wall time is reported as
derived GFLOP/s)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import params as P
from repro.models.api import family_module

B, T = 2, 128


def main() -> None:
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        mod = family_module(cfg)
        params = P.init_tree(jax.random.PRNGKey(0), mod.param_defs(cfg))
        key = jax.random.PRNGKey(1)
        batch = {
            "tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
        }
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
        if cfg.family == "vlm":
            from repro.models.vlm import VIT_DIM

            batch["patches"] = jax.random.normal(key, (B, cfg.num_patches, VIT_DIM))
            batch["tokens"] = batch["tokens"][:, : T - cfg.num_patches]
            batch["labels"] = batch["labels"][:, : T - cfg.num_patches]

        # reprolint: disable=retrace-hazard -- one compile per swept
        # architecture; time_fn warms up past it.
        grad_fn = jax.jit(jax.value_and_grad(lambda p: mod.loss_fn(cfg, p, batch)))
        t_train = time_fn(grad_fn, params, warmup=1, iters=3)
        emit(f"lm/{arch}/train_step", t_train, f"B{B}xT{T}")

        state = mod.init_decode_state(cfg, B, 64)
        tok = jnp.zeros((B,), jnp.int32)
        # reprolint: disable=retrace-hazard -- ditto: per-architecture compile.
        dec = jax.jit(lambda s, t: mod.decode_step(cfg, params, s, t))
        t_dec = time_fn(dec, state, tok, warmup=1, iters=5)
        emit(f"lm/{arch}/decode_step", t_dec, f"B{B}")


if __name__ == "__main__":
    main()
