"""Quantized resident scenes: bytes, throughput, and fidelity vs f32.

The fused raster path can stream *compressed* chunks (``core.quant``: f32
positions/quats, fp16 SH DC, int8 per-chunk/per-band SH bands 1-3, int8
opacity/log-scales) and decode to f32 lanes in registers
(``kernels.fused_raster``). This benchmark measures the whole trade on the
serving shape (cameras inside the cloud, frustum-culled SceneTree):

* resident bytes of the f32 vs quantized tree (``SceneTree.memory_stats``)
  — the multi-scene-serving constraint and the sharded all-gather payload;
* sequential req/s of the fused path over the f32 tree vs the quantized
  tree (decode-in-kernel must not give back the fused win);
* PSNR of the quantized render vs the f32 fused render, decomposed by
  field group (hybrid clouds: only-SH-quantized, only-geometry-quantized,
  DC-at-fp16) so a fidelity regression names its culprit.

``--tiny`` is the CI smoke: asserts >= 3x SH-bytes reduction and PSNR >=
40 dB vs the f32 fused render on a small clustered scene.

    PYTHONPATH=src python -m benchmarks.bench_compress [--tiny]
"""

from __future__ import annotations

import argparse
import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from benchmarks.bench_fused import (
    CAMERAS,
    IMAGE_SIZE,
    ITERS,
    LEAF_SIZE,
    TINY_IMAGE_SIZE,
    TINY_LEAF,
    TINY_N,
    _seq_req_s,
    inside_cameras,
    make_scene,
)
from benchmarks.common import emit
from repro.core import (
    RenderConfig,
    build_scene_tree,
    dequantize_gaussians,
    quantize_gaussians,
    visibility_stats,
)
from repro.core.quant import F32_RECORD_BYTES, QUANT_RECORD_BYTES
from repro.core.render import render_jit

SWEEP = (
    ("uniform", (100_000,)),
    ("clustered", (100_000, 1_000_000)),
)
# Hybrid-cloud PSNR decomposition is O(extra clouds in memory); cap it.
DECOMPOSE_MAX_N = 200_000


def _psnr(a, b) -> float:
    mse = float(jnp.mean((jnp.asarray(a) - jnp.asarray(b)) ** 2))
    return float("inf") if mse == 0.0 else -10.0 * math.log10(mse)


def _min_psnr(a_imgs, b_imgs) -> float:
    return min(_psnr(a, b) for a, b in zip(a_imgs, b_imgs))


def psnr_decomposition(g, cams, cfg, leaf_size: int) -> dict:
    """PSNR vs the f32 render with one field group quantized at a time.

    The hybrids splice dequantized fields into the original cloud, so each
    number isolates one storage decision: SH bands at int8, geometry
    (log-scales + opacity) at int8, DC at fp16.
    """
    deq = dequantize_gaussians(quantize_gaussians(g, leaf_size))
    hybrids = {
        "sh_bands_int8": dataclasses.replace(
            g, sh=g.sh.at[:, 1:, :].set(deq.sh[:, 1:, :])
        ),
        "geometry_int8": dataclasses.replace(
            g, log_scales=deq.log_scales, opacity_logit=deq.opacity_logit
        ),
        "dc_fp16": dataclasses.replace(
            g, sh=g.sh.at[:, 0, :].set(deq.sh[:, 0, :])
        ),
        "all_quantized": deq,
    }
    f32_imgs = [render_jit(g, c, cfg) for c in cams]
    out = {}
    for name, hg in hybrids.items():
        out[name] = _min_psnr(
            [render_jit(hg, c, cfg) for c in cams], f32_imgs
        )
    return out


def bench_scene(
    kind: str,
    n: int,
    *,
    image_size: int,
    leaf_size: int,
    iters: int,
    decompose: bool | None = None,
) -> dict:
    g = make_scene(kind, n)
    tree_f = build_scene_tree(g, leaf_size=leaf_size)
    tree_q = build_scene_tree(g, leaf_size=leaf_size, compress="int8")
    cams = inside_cameras(CAMERAS, image_size)

    base = RenderConfig(raster_path="pallas_fused", cull=True)
    stats = [visibility_stats(tree_f, c, base) for c in cams]
    capacity = max(s["num_visible"] for s in stats)
    cfg = base.replace(visible_capacity=capacity)

    mem_f = tree_f.memory_stats()
    mem_q = tree_q.memory_stats()
    byte_ratio = mem_q["total_bytes"] / mem_f["total_bytes"]
    sh_reduction = mem_f["sh_bytes"] / mem_q["sh_bytes"]

    f32_req_s, f32_imgs = _seq_req_s(tree_f, cams, cfg, iters)
    q_req_s, q_imgs = _seq_req_s(tree_q, cams, cfg, iters)
    rel = q_req_s / f32_req_s
    psnr = _min_psnr(q_imgs, f32_imgs)

    tag = f"compress/{kind}_{n}"
    emit(
        f"{tag}_resident_bytes",
        mem_q["total_bytes"] / 1e6,
        f"{byte_ratio:.3f}x_f32_sh{sh_reduction:.2f}x",
    )
    emit(f"{tag}_f32_req_s", 1e6 / f32_req_s, f"{f32_req_s:.2f}req_s")
    emit(
        f"{tag}_quant_req_s",
        1e6 / q_req_s,
        f"{rel:.2f}x_f32_psnr{psnr:.1f}dB",
    )

    entry = {
        "gaussians": n,
        "image_size": image_size,
        "leaf_size": leaf_size,
        "visible_capacity_chunks": capacity,
        "visible_fraction_mean": float(
            np.mean([s["visible_fraction"] for s in stats])
        ),
        "f32_bytes": mem_f["total_bytes"],
        "quant_bytes": mem_q["total_bytes"],
        "byte_ratio": byte_ratio,
        "sh_bytes_reduction": sh_reduction,
        # Sharded wire cost shrinks with the same record ratio (the
        # all-gather ships the quantized pytree, decoded per device).
        "record_bytes": {
            "f32": F32_RECORD_BYTES,
            "quant": QUANT_RECORD_BYTES,
        },
        "f32_req_s": f32_req_s,
        "quant_req_s": q_req_s,
        "quant_rel_req_s": rel,
        "psnr_db": psnr,
    }
    if decompose is None:
        decompose = n <= DECOMPOSE_MAX_N
    if decompose:
        entry["psnr_decomposition_db"] = psnr_decomposition(
            g, cams, cfg.replace(cull=False), leaf_size
        )
        for name, v in entry["psnr_decomposition_db"].items():
            emit(f"{tag}_psnr_{name}", v, f"{v:.1f}dB")
    return entry


def main(argv: tuple[str, ...] | list[str] = ()) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny",
        action="store_true",
        help="CI smoke: small clustered scene, asserts >= 3x SH-bytes "
        "reduction and PSNR >= 40 dB vs the f32 fused render",
    )
    args = ap.parse_args(list(argv))

    if args.tiny:
        entry = bench_scene(
            "clustered",
            TINY_N,
            image_size=TINY_IMAGE_SIZE,
            leaf_size=TINY_LEAF,
            iters=1,
            decompose=True,
        )
        assert entry["sh_bytes_reduction"] >= 3.0, entry
        assert entry["byte_ratio"] <= 0.45, entry
        assert entry["psnr_db"] >= 40.0, entry
        print(
            f"# tiny smoke OK: {entry['byte_ratio']:.3f}x resident bytes, "
            f"SH {entry['sh_bytes_reduction']:.2f}x smaller, "
            f"PSNR {entry['psnr_db']:.1f} dB, "
            f"quant {entry['quant_rel_req_s']:.2f}x f32 req/s"
        )
        return {"clustered": {str(TINY_N): entry}}

    metrics: dict = {}
    for kind, sizes in SWEEP:
        metrics[kind] = {}
        for n in sizes:
            metrics[kind][str(n)] = bench_scene(
                kind,
                n,
                image_size=IMAGE_SIZE,
                leaf_size=LEAF_SIZE,
                iters=ITERS,
            )
    return metrics


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
